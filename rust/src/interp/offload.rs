//! Off-thread analysis: overlap interpretation with analyzer folding.
//!
//! The inline chunked path ([`Machine::run`]) stalls the interpreter while
//! the analyzer stack folds each chunk — on analyzer-heavy profiles the
//! interpreter spends most of its wall time waiting. This module moves the
//! fold off the interpreter thread, in two topologies.
//!
//! ## Offload: 1 producer + 1 consumer ([`run_offload`])
//!
//! The interpreter fills owned [`EventChunk`]s and ships them over a
//! bounded `sync_channel`; a dedicated analysis thread (which owns the
//! `Instrument` stack for the duration of the run) flushes each chunk —
//! building its SoA [`ChunkLanes`](super::events::ChunkLanes) view there,
//! off the interpreter's critical path — and recycles the empty buffer
//! back over a return channel. The interpreter produces chunk *N+1* while
//! the analyzers fold chunk *N*.
//!
//! ## Sharded: 1 producer + 1 broadcaster + N workers ([`sharded`])
//!
//! With every metric family enabled the single analysis thread becomes
//! the bottleneck. [`sharded::run_sharded`] fans each chunk out to a small
//! pool of analyzer **workers**, each owning a disjoint shard of the
//! analyzer set (the `analysis` layer shards by metric family along the
//! lane boundaries: tags, memory lanes, event slices):
//!
//! ```text
//!  interpreter ──EventChunk──▶ broadcaster ──Arc<EventChunk>──▶ worker 0 (shard 0)
//!   (owns the     sync_channel  (builds the   one sync_channel ▶ worker 1 (shard 1)
//!    machine)     depth 2       union lanes)  per worker       ▶ worker N-1
//!        ▲                                                          │
//!        └────────────── countdown-return: each worker sends its ───┘
//!            Arc back; the producer recycles the buffer when the
//!            last reference arrives (`Arc::try_unwrap`)
//! ```
//!
//! The broadcaster builds the chunk's lanes **once**, restricted to the
//! union of every shard's [`Instrument::lane_needs`] mask, then shares the
//! chunk immutably; no analyzer state is shared between workers, so the
//! shards need no locks. Ownership of each buffer makes a full cycle:
//! producer → broadcaster → (shared read-only by all workers) → producer.
//!
//! ## Memory and backpressure
//!
//! Both topologies cycle a fixed pool of owned chunks. Offload:
//! [`OFFLOAD_POOL_CHUNKS`] buffers — one in the interpreter's hands, up to
//! [`OFFLOAD_QUEUE_CHUNKS`] queued, one being folded. Sharded:
//! [`sharded::SHARDED_POOL_CHUNKS`] buffers, with each worker's input
//! queue bounded separately. Shipping waits for a recycled buffer, so when
//! the analysis side is slower the interpreter blocks instead of piling up
//! unbounded trace — memory is bounded by the pool no matter how lopsided
//! the sides are, and a single slow worker stalls the broadcast (and so,
//! eventually, the interpreter) rather than growing a queue (stressed in
//! `rust/tests/prop_chunked.rs`).
//!
//! ## Equivalence
//!
//! Chunks arrive in emission order over FIFO channels — the broadcast
//! preserves that order per worker — and every analyzer is a pure fold
//! over the event sequence, so offloaded and sharded metrics are
//! **bit-identical** to the inline chunked and per-event paths — one
//! property test gates all four. `ExecStats::wall_s` is rewritten to span
//! the whole run *including* the analysis drain, so `events_per_sec`
//! stays comparable across [`PipelineMode`]s.
//!
//! ## Supervision and failure domains
//!
//! Each pipeline thread is its own failure domain. The supervised entry
//! points ([`run_offload_supervised`], [`sharded::run_sharded_supervised`])
//! run every analysis-side thread under `catch_unwind` and convert a dead
//! thread into a structured [`ShardFailure`](crate::fault::ShardFailure)
//! in the returned [`PipelineRun`] instead of unwinding the process:
//!
//! * **Worker dies** (sharded): its channel ends drop during the unwind;
//!   the broadcaster sees the send fail, prunes that worker from its live
//!   list, and keeps feeding the survivors, whose metrics stay
//!   bit-identical to a clean run restricted to their shards.
//! * **Broadcaster / offload analysis thread dies**: its receiver drops,
//!   so the producer's next ship detaches (events are discarded, the
//!   interpreter still completes) and every starved shard is reported
//!   failed.
//! * **Producer (interpreter) faults** — injected error, watchdog expiry,
//!   or injected panic — surface as a typed `Err` from the run; dropping
//!   the courier closes the chunk channel, so the analysis side drains
//!   what's in flight and exits on its own.
//!
//! **Countdown-return with dead workers:** a worker that unwinds releases
//! its `Arc` references (held chunk and queued channel buffers) during
//! teardown, so a surviving worker's last returned reference still
//! unwraps and recycles the buffer. A chunk whose *every* recipient died
//! is deallocated rather than returned — the pool shrinks by at most that
//! worker's queue depth + 1, never wedges — and [`SHARDED_POOL_CHUNKS`]
//! (sized queue-depths + 3) keeps buffers circulating past any single
//! failure. Every `EventChunk` is therefore returned or dropped, never
//! leaked into a wedged `sync_channel`.
//!
//! The watchdog ([`SuperviseOpts::timeout_s`](crate::fault::SuperviseOpts))
//! is checked at chunk boundaries on the producer, and pool refills use
//! `recv_timeout` while it is armed, so a stalled analysis side cannot
//! block the producer past the deadline; the deterministic fault plan
//! (`--inject-fault`, [`crate::fault::FaultPlan`]) ticks once per chunk at
//! each site to prove all of the above under test (`rust/tests/prop_faults.rs`).
//! With default [`SuperviseOpts`](crate::fault::SuperviseOpts) the
//! supervised paths are bit-identical to the unsupervised wrappers.

pub mod sharded;

use std::mem;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::time::Instant;

use anyhow::{bail, Result};

use super::events::{EventChunk, Instrument, TraceEvent};
use super::machine::{EventSink, Machine, Outcome};
use crate::fault::{
    panic_message, ArmedFault, Deadline, FaultPlan, PanicError, Role, ShardFailure, SuperviseOpts,
};
use crate::ir::Program;

/// Bound of the full-chunk channel: how many filled chunks may queue
/// between the interpreter and the analysis thread.
pub const OFFLOAD_QUEUE_CHUNKS: usize = 2;

/// Owned chunks cycling between the threads: one being filled, up to
/// [`OFFLOAD_QUEUE_CHUNKS`] in flight, one being folded.
pub const OFFLOAD_POOL_CHUNKS: usize = OFFLOAD_QUEUE_CHUNKS + 2;

/// Analyzer-worker pool sizing for [`PipelineMode::Sharded`] — the value
/// of the CLI `--workers` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Workers {
    /// Size the pool from the enabled metric families: one worker per
    /// non-empty shard group (`analysis::ShardPlan` decides — e.g.
    /// `--metrics mix` collapses to a single worker).
    #[default]
    Auto,
    /// Ask for exactly this many workers; the planner clamps to the number
    /// of non-empty family groups so no worker ever idles on an empty
    /// shard.
    Fixed(usize),
}

impl Workers {
    /// Parse the CLI `--workers` value: `auto` or a positive integer.
    pub fn from_name(s: &str) -> Result<Self> {
        let s = s.trim();
        if s == "auto" {
            return Ok(Workers::Auto);
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Workers::Fixed(n)),
            _ => bail!("--workers expects 'auto' or a positive integer, got '{s}'"),
        }
    }
}

impl std::fmt::Display for Workers {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Workers::Auto => write!(f, "auto"),
            Workers::Fixed(n) => write!(f, "{n}"),
        }
    }
}

/// How the profiling pipeline delivers chunks to the analyzers. Threaded
/// CLI (`--pipeline`) → `coordinator::pipeline` → every worker's run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// Analyzers fold each chunk on the interpreter thread (the reference
    /// semantics; lowest latency for tiny runs).
    #[default]
    Inline,
    /// Analyzers fold on a dedicated thread, overlapped with
    /// interpretation (fastest for realistic single-threaded analysis).
    Offload,
    /// Analyzers shard by metric family across a pool of workers, each
    /// chunk broadcast to all of them (fastest when many families are
    /// enabled; see [`sharded`]).
    Sharded {
        /// Worker pool sizing (`--workers`).
        workers: Workers,
    },
}

impl PipelineMode {
    pub fn name(self) -> &'static str {
        match self {
            PipelineMode::Inline => "inline",
            PipelineMode::Offload => "offload",
            PipelineMode::Sharded { .. } => "sharded",
        }
    }

    /// Parse the CLI `--pipeline` value (`sharded` defaults to
    /// `--workers auto`; the CLI layers an explicit worker count on top).
    pub fn from_name(s: &str) -> Result<Self> {
        match s.trim() {
            "inline" => Ok(PipelineMode::Inline),
            "offload" => Ok(PipelineMode::Offload),
            "sharded" => Ok(PipelineMode::Sharded { workers: Workers::Auto }),
            other => bail!("unknown pipeline mode '{other}' (inline|offload|sharded)"),
        }
    }
}

/// Where an off-thread delivery sink reacquires empty chunk buffers — the
/// one piece that differs between the offload and sharded topologies.
/// Blocking here is the backpressure: the pool bounds in-flight memory
/// however slow the analysis side is.
trait BufferSource {
    /// A reusable empty buffer, or `None` when the analysis side is gone
    /// (panic teardown).
    fn next_buffer(&mut self) -> Option<EventChunk>;
}

/// Offload topology's source: recycled buffers come back whole over the
/// analysis thread's return channel.
struct FreeList {
    rx: Receiver<EventChunk>,
    /// Armed watchdog deadline: bounds the wait so a stalled analysis
    /// thread cannot block the producer past `--app-timeout`.
    deadline: Deadline,
}

impl BufferSource for FreeList {
    fn next_buffer(&mut self) -> Option<EventChunk> {
        match self.deadline.remaining() {
            None => self.rx.recv().ok(),
            // timeout and disconnect both detach the courier; the courier
            // then reports the expiry (deadline check) or the join reports
            // the dead analysis thread
            Some(left) => self.rx.recv_timeout(left).ok(),
        }
    }
}

/// Interpreter-side delivery shared by both off-thread topologies: fills
/// owned chunks and ships them over the full-chunk channel, reacquiring
/// buffers from the topology-specific [`BufferSource`]. Written once so
/// the flush points — which mirror the inline `Chunked` sink exactly
/// (block boundaries, mid-giant-block fills, end of run) — can never
/// drift between modes: chunk boundaries, and therefore lane sweeps, are
/// identical everywhere (the cross-mode bit-identity property depends on
/// this).
struct CourierSink<S: BufferSource> {
    full: SyncSender<EventChunk>,
    source: S,
    chunk: EventChunk,
    /// Set when the analysis side is gone (panic teardown) or the
    /// watchdog expired: buffered events are dropped and the runner
    /// surfaces the join failures or the supervision error.
    detached: bool,
    /// Producer-site fault ticker (`--inject-fault …@interp`).
    armed: ArmedFault,
    /// Per-app watchdog, checked once per shipped chunk.
    deadline: Deadline,
    /// Supervision error pending pickup by the interpreter loop
    /// (`EventSink::take_error`).
    error: Option<anyhow::Error>,
}

impl<S: BufferSource> CourierSink<S> {
    fn new(full: SyncSender<EventChunk>, source: S, capacity: usize) -> Self {
        CourierSink {
            full,
            source,
            chunk: EventChunk::with_capacity(capacity),
            detached: false,
            armed: FaultPlan::none().arm(&[]),
            deadline: Deadline::none(),
            error: None,
        }
    }

    fn supervise(&mut self, armed: ArmedFault, deadline: Deadline) {
        self.armed = armed;
        self.deadline = deadline;
    }

    fn ship(&mut self) {
        if self.chunk.is_empty() {
            return;
        }
        if self.error.is_none() {
            if let Err(e) = self.armed.tick() {
                self.error = Some(e.into());
            } else if let Err(e) = self.deadline.check() {
                self.error = Some(e.into());
            }
        }
        if self.error.is_some() {
            // the run is about to bail at the next block boundary — stop
            // feeding the analysis side so teardown starts immediately
            self.chunk.clear();
            return;
        }
        if !self.detached {
            match self.source.next_buffer() {
                Some(fresh) => {
                    let full = mem::replace(&mut self.chunk, fresh);
                    if self.full.send(full).is_err() {
                        self.detached = true;
                    }
                    return;
                }
                None => self.detached = true,
            }
        }
        self.chunk.clear();
    }
}

impl<S: BufferSource> EventSink for CourierSink<S> {
    #[inline]
    fn event(&mut self, ev: TraceEvent) {
        // a single block larger than the buffer still ships safely mid-block
        if self.chunk.is_full() {
            self.ship();
        }
        self.chunk.push(ev);
    }

    #[inline]
    fn block_boundary(&mut self, upcoming: usize) {
        if self.chunk.needs_flush_for_block(upcoming) {
            self.ship();
        }
    }

    fn finish(&mut self) {
        self.ship();
    }

    fn take_error(&mut self) -> Option<anyhow::Error> {
        self.error.take()
    }
}

/// Result of a supervised pipeline run: the interpreter's outcome plus
/// the analysis-side failures that were isolated instead of unwinding
/// the process. An empty `failures` is a fully clean run.
#[derive(Debug)]
pub struct PipelineRun {
    pub outcome: Outcome,
    pub failures: Vec<ShardFailure>,
}

/// Execute `machine` to completion with the analyzers folding on a
/// dedicated thread. `sink` is moved to that thread for the duration of
/// the run (hence `Send`) and handed back — through the borrow — when this
/// returns; metrics are bit-identical to [`Machine::run`]. Unsupervised
/// wrapper: no faults, no watchdog, and an analysis-side failure becomes
/// an `Err` ([`run_offload_supervised`] reports it structurally instead).
pub fn run_offload(
    machine: &mut Machine<'_>,
    sink: &mut (dyn Instrument + Send),
) -> Result<Outcome> {
    let run = run_offload_supervised(machine, sink, SuperviseOpts::default())?;
    if let Some(f) = run.failures.into_iter().next() {
        bail!("offload analysis thread failed: {}", f.message);
    }
    Ok(run.outcome)
}

/// [`run_offload`] under supervision: the analysis thread runs under
/// `catch_unwind` (its death degrades the run to a [`ShardFailure`]
/// instead of unwinding the process), the producer arms the `interp`
/// fault site and the watchdog, and offload's single analysis thread
/// collapses the `broadcaster` and `worker:*` fault sites onto itself.
pub fn run_offload_supervised(
    machine: &mut Machine<'_>,
    sink: &mut (dyn Instrument + Send),
    sup: SuperviseOpts,
) -> Result<PipelineRun> {
    let capacity = machine.chunk_capacity();
    let deadline = sup.deadline();
    let fault = sup.fault;
    let t0 = Instant::now();
    let (mut outcome, failures) =
        std::thread::scope(|s| -> Result<(Outcome, Vec<ShardFailure>)> {
            let (full_tx, full_rx) = mpsc::sync_channel::<EventChunk>(OFFLOAD_QUEUE_CHUNKS);
            let (free_tx, free_rx) = mpsc::channel::<EventChunk>();
            for _ in 0..OFFLOAD_POOL_CHUNKS - 1 {
                free_tx.send(EventChunk::with_capacity(capacity)).expect("free channel open");
            }
            let worker = s.spawn(move || {
                // the analysis thread owns the sink until the chunk channel
                // closes; lanes are built here (per chunk, inside
                // flush_into). A panic is caught and the unwind drops the
                // channel ends, so the producer detaches cleanly.
                catch_unwind(AssertUnwindSafe(move || {
                    let mut armed = fault.arm(&[Role::Broadcaster, Role::AnyWorker]);
                    while let Ok(mut chunk) = full_rx.recv() {
                        // only panic/stall can target this site, so the
                        // tick never yields an interpreter error here
                        let _ = armed.tick();
                        chunk.flush_into(&mut *sink);
                        // interpreter may already be gone on error teardown
                        let _ = free_tx.send(chunk);
                    }
                }))
                .map_err(panic_message)
            });
            let mut delivery =
                CourierSink::new(full_tx, FreeList { rx: free_rx, deadline }, capacity);
            delivery.supervise(fault.arm(&[Role::Interp]), deadline);
            let run = catch_unwind(AssertUnwindSafe(|| machine.run_with(&mut delivery)));
            // closing the chunk channel lets the worker drain what's in
            // flight and exit; join before returning so all events are
            // folded (or the failure is recorded)
            drop(delivery);
            let mut failures = Vec::new();
            match worker.join() {
                Ok(Ok(())) => {}
                Ok(Err(message)) => {
                    failures.push(ShardFailure { shard: 0, families: Vec::new(), message })
                }
                // not reachable: the thread body is fully caught
                Err(payload) => std::panic::resume_unwind(payload),
            }
            match run {
                Ok(res) => Ok((res?, failures)),
                // an injected producer panic: report it typed, after the
                // analysis side has been joined (teardown stays clean)
                Err(payload) => Err(PanicError::new("interp", panic_message(payload)).into()),
            }
        })?;
    // the interpreter's own timer stopped at Ret, before the analysis
    // thread finished draining; report the overlap-inclusive wall time so
    // events_per_sec stays honest across pipeline modes
    outcome.stats.wall_s = t0.elapsed().as_secs_f64();
    Ok(PipelineRun { outcome, failures })
}

/// One-shot convenience mirroring [`super::machine::run_program`], with the
/// delivery mode as a knob: build a machine, run, return outcome and
/// machine (for post-run buffer inspection). Note that `Sharded` here runs
/// the whole undivided `sink` on a **single** worker (the broadcast
/// topology with one consumer — the `workers` sizing is ignored):
/// family-level sharding needs one stack per shard, which is the
/// `analysis` layer's job (`analysis::profile_sharded`,
/// `analysis::ShardPlan`). Metrics are bit-identical in every mode.
pub fn run_program_mode<'p>(
    prog: &'p Program,
    sink: &mut (dyn Instrument + Send),
    mode: PipelineMode,
) -> Result<(Outcome, Machine<'p>)> {
    let mut m = Machine::new(prog)?;
    let out = match mode {
        PipelineMode::Inline => m.run(sink)?,
        PipelineMode::Offload => run_offload(&mut m, sink)?,
        // a single undivided sink: the full sharded topology with one
        // worker (family sharding is the analysis layer's job — see
        // `analysis::ShardPlan` for the multi-stack entry points)
        PipelineMode::Sharded { .. } => sharded::run_sharded(&mut m, &mut [sink])?,
    };
    Ok((out, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::events::Counter;
    use crate::ir::ProgramBuilder;

    fn loop_program(n: i64) -> Program {
        let mut b = ProgramBuilder::new("off");
        let a = b.alloc_f64("a", 64);
        let len = b.const_i(64);
        let trip = b.const_i(n);
        b.counted_loop(trip, |b, i| {
            let idx = b.rem(i, len);
            let v = b.load_f64(a, idx);
            let w = b.fadd(v, v);
            b.store_f64(a, idx, w);
        });
        b.finish(None)
    }

    #[test]
    fn mode_parsing_roundtrips() {
        assert_eq!(PipelineMode::from_name("inline").unwrap(), PipelineMode::Inline);
        assert_eq!(PipelineMode::from_name(" offload ").unwrap(), PipelineMode::Offload);
        assert_eq!(
            PipelineMode::from_name("sharded").unwrap(),
            PipelineMode::Sharded { workers: Workers::Auto }
        );
        assert!(PipelineMode::from_name("bogus").is_err());
        assert_eq!(PipelineMode::default().name(), "inline");
        assert_eq!(PipelineMode::Sharded { workers: Workers::Fixed(3) }.name(), "sharded");
    }

    #[test]
    fn workers_parsing() {
        assert_eq!(Workers::from_name("auto").unwrap(), Workers::Auto);
        assert_eq!(Workers::from_name(" 4 ").unwrap(), Workers::Fixed(4));
        assert!(Workers::from_name("0").is_err());
        assert!(Workers::from_name("-1").is_err());
        assert!(Workers::from_name("many").is_err());
        assert_eq!(Workers::Auto.to_string(), "auto");
        assert_eq!(Workers::Fixed(2).to_string(), "2");
    }

    #[test]
    fn offload_counts_match_inline() {
        let p = loop_program(5000);
        let mut inline = Counter::default();
        let mut offl = Counter::default();
        let o1 = Machine::new(&p).unwrap().run(&mut inline).unwrap();
        let o2 = run_offload(&mut Machine::new(&p).unwrap(), &mut offl).unwrap();
        assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs);
        assert_eq!(o1.stats.dyn_blocks, o2.stats.dyn_blocks);
        assert_eq!(o1.stats.dyn_branches, o2.stats.dyn_branches);
        assert_eq!(
            (inline.instrs, inline.blocks, inline.branches, inline.loads, inline.stores),
            (offl.instrs, offl.blocks, offl.branches, offl.loads, offl.stores)
        );
        assert!(o2.stats.wall_s > 0.0);
        assert!(o2.stats.events_per_sec() > 0.0);
    }

    #[test]
    fn run_program_mode_selects_delivery() {
        let p = loop_program(100);
        let mut a = Counter::default();
        let mut b = Counter::default();
        let mut c = Counter::default();
        let (o1, _) = run_program_mode(&p, &mut a, PipelineMode::Inline).unwrap();
        let (o2, _) = run_program_mode(&p, &mut b, PipelineMode::Offload).unwrap();
        let (o3, _) =
            run_program_mode(&p, &mut c, PipelineMode::Sharded { workers: Workers::Auto }).unwrap();
        assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs);
        assert_eq!(o1.stats.dyn_instrs, o3.stats.dyn_instrs);
        assert_eq!(a.instrs, b.instrs);
        assert_eq!(a.instrs, c.instrs);
    }

    #[test]
    fn analyzer_panic_degrades_instead_of_unwinding() {
        struct Bomb(u64);
        impl Instrument for Bomb {
            fn on_event(&mut self, _ev: &TraceEvent) {
                self.0 += 1;
                if self.0 == 100 {
                    panic!("analyzer bomb");
                }
            }
        }
        let p = loop_program(5000);
        let mut bomb = Bomb(0);
        let run = run_offload_supervised(
            &mut Machine::new(&p).unwrap(),
            &mut bomb,
            SuperviseOpts::default(),
        )
        .unwrap();
        assert_eq!(run.failures.len(), 1);
        assert_eq!(run.failures[0].shard, 0);
        assert!(run.failures[0].message.contains("analyzer bomb"));
        // the producer still ran the program to completion (degraded run)
        assert!(run.outcome.stats.dyn_instrs > 0);
        // the unsupervised wrapper surfaces the same death as an error,
        // not a process unwind
        let mut bomb = Bomb(0);
        assert!(run_offload(&mut Machine::new(&p).unwrap(), &mut bomb).is_err());
    }

    #[test]
    fn injected_interp_error_surfaces_typed() {
        let p = loop_program(5000);
        let mut c = Counter::default();
        let sup = SuperviseOpts::default()
            .with_fault(FaultPlan::from_spec("interp-error@interp").unwrap());
        let err = run_offload_supervised(&mut Machine::new(&p).unwrap(), &mut c, sup).unwrap_err();
        assert!(err.downcast_ref::<crate::fault::InjectedFault>().is_some());
    }

    #[test]
    fn interpreter_error_propagates_through_offload() {
        let mut b = ProgramBuilder::new("dz");
        let x = b.const_i(1);
        let z = b.const_i(0);
        b.div(x, z);
        let p = b.finish(None);
        let mut c = Counter::default();
        let err = run_offload(&mut Machine::new(&p).unwrap(), &mut c);
        assert!(err.is_err());
    }
}
