//! Off-thread analysis: overlap interpretation with analyzer folding.
//!
//! The inline chunked path ([`Machine::run`]) stalls the interpreter while
//! the analyzer stack folds each chunk — on analyzer-heavy profiles the
//! interpreter spends most of its wall time waiting. This module moves the
//! fold to a dedicated **analysis thread**: the interpreter fills owned
//! [`EventChunk`]s and ships them over a bounded `sync_channel`; the
//! analysis thread (which owns the `Instrument` stack for the duration of
//! the run) flushes each chunk — building its SoA
//! [`ChunkLanes`](super::events::ChunkLanes) view there, off the
//! interpreter's critical path — and recycles the empty buffer back over a
//! return channel. The interpreter produces chunk *N+1* while the
//! analyzers fold chunk *N*.
//!
//! ## Memory and backpressure
//!
//! A fixed pool of [`OFFLOAD_POOL_CHUNKS`] owned chunks cycles between the
//! two threads (double buffering plus queue slack): one in the
//! interpreter's hands, up to [`OFFLOAD_QUEUE_CHUNKS`] queued, one being
//! folded. Shipping waits for a recycled buffer, so when the analysis
//! thread is the slower side the interpreter blocks instead of piling up
//! unbounded trace — memory is bounded by the pool no matter how lopsided
//! the two sides are (stressed in `rust/tests/prop_chunked.rs`).
//!
//! ## Equivalence
//!
//! Chunks arrive in emission order over a FIFO channel and every analyzer
//! is a pure fold over the event sequence, so offloaded metrics are
//! **bit-identical** to the inline chunked and per-event paths — the same
//! property test gates all three. `ExecStats::wall_s` is rewritten to span
//! the whole run *including* the analysis thread's drain, so
//! `events_per_sec` stays comparable across [`PipelineMode`]s.

use std::mem;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::time::Instant;

use anyhow::{bail, Result};

use super::events::{EventChunk, Instrument, TraceEvent};
use super::machine::{EventSink, Machine, Outcome};
use crate::ir::Program;

/// Bound of the full-chunk channel: how many filled chunks may queue
/// between the interpreter and the analysis thread.
pub const OFFLOAD_QUEUE_CHUNKS: usize = 2;

/// Owned chunks cycling between the threads: one being filled, up to
/// [`OFFLOAD_QUEUE_CHUNKS`] in flight, one being folded.
pub const OFFLOAD_POOL_CHUNKS: usize = OFFLOAD_QUEUE_CHUNKS + 2;

/// How the profiling pipeline delivers chunks to the analyzers. Threaded
/// CLI (`--pipeline`) → `coordinator::pipeline` → every worker's run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// Analyzers fold each chunk on the interpreter thread (the reference
    /// semantics; lowest latency for tiny runs).
    #[default]
    Inline,
    /// Analyzers fold on a dedicated thread, overlapped with
    /// interpretation (fastest for realistic workload sizes).
    Offload,
}

impl PipelineMode {
    pub fn name(self) -> &'static str {
        match self {
            PipelineMode::Inline => "inline",
            PipelineMode::Offload => "offload",
        }
    }

    /// Parse the CLI `--pipeline` value.
    pub fn from_name(s: &str) -> Result<Self> {
        match s.trim() {
            "inline" => Ok(PipelineMode::Inline),
            "offload" => Ok(PipelineMode::Offload),
            other => bail!("unknown pipeline mode '{other}' (inline|offload)"),
        }
    }
}

/// Interpreter-side delivery: fills owned chunks and cycles them through
/// the channel pair. Mirrors the inline `Chunked` sink's flush points
/// exactly (block boundaries, mid-giant-block fills, end of run) so chunk
/// boundaries — and therefore lane sweeps — are identical across modes.
struct OffloadSink {
    full: SyncSender<EventChunk>,
    free: Receiver<EventChunk>,
    chunk: EventChunk,
    /// Set when the analysis thread is gone (panic teardown): buffered
    /// events are dropped and `run_offload` surfaces the join error.
    detached: bool,
}

impl OffloadSink {
    fn ship(&mut self) {
        if self.chunk.is_empty() {
            return;
        }
        if !self.detached {
            // backpressure: wait for a recycled buffer before shipping —
            // the pool bounds in-flight memory however slow the analyzers
            match self.free.recv() {
                Ok(fresh) => {
                    let full = mem::replace(&mut self.chunk, fresh);
                    if self.full.send(full).is_err() {
                        self.detached = true;
                    }
                    return;
                }
                Err(_) => self.detached = true,
            }
        }
        self.chunk.clear();
    }
}

impl EventSink for OffloadSink {
    #[inline]
    fn event(&mut self, ev: TraceEvent) {
        // a single block larger than the buffer still ships safely mid-block
        if self.chunk.is_full() {
            self.ship();
        }
        self.chunk.push(ev);
    }

    #[inline]
    fn block_boundary(&mut self, upcoming: usize) {
        if self.chunk.needs_flush_for_block(upcoming) {
            self.ship();
        }
    }

    fn finish(&mut self) {
        self.ship();
    }
}

/// Execute `machine` to completion with the analyzers folding on a
/// dedicated thread. `sink` is moved to that thread for the duration of
/// the run (hence `Send`) and handed back — through the borrow — when this
/// returns; metrics are bit-identical to [`Machine::run`].
pub fn run_offload(
    machine: &mut Machine<'_>,
    sink: &mut (dyn Instrument + Send),
) -> Result<Outcome> {
    let capacity = machine.chunk_capacity();
    let t0 = Instant::now();
    let mut outcome = std::thread::scope(|s| -> Result<Outcome> {
        let (full_tx, full_rx) = mpsc::sync_channel::<EventChunk>(OFFLOAD_QUEUE_CHUNKS);
        let (free_tx, free_rx) = mpsc::channel::<EventChunk>();
        for _ in 0..OFFLOAD_POOL_CHUNKS - 1 {
            free_tx.send(EventChunk::with_capacity(capacity)).expect("free channel open");
        }
        let worker = s.spawn(move || {
            // the analysis thread owns the sink until the chunk channel
            // closes; lanes are built here (per chunk, inside flush_into)
            while let Ok(mut chunk) = full_rx.recv() {
                chunk.flush_into(&mut *sink);
                // interpreter may already be gone on error teardown
                let _ = free_tx.send(chunk);
            }
        });
        let mut delivery = OffloadSink {
            full: full_tx,
            free: free_rx,
            chunk: EventChunk::with_capacity(capacity),
            detached: false,
        };
        let run = machine.run_with(&mut delivery);
        // closing the chunk channel lets the worker drain what's in flight
        // and exit; join before returning so all events are folded
        drop(delivery);
        if let Err(payload) = worker.join() {
            // an analyzer panic must surface with its original message,
            // exactly as it would on the inline path
            std::panic::resume_unwind(payload);
        }
        run
    })?;
    // the interpreter's own timer stopped at Ret, before the analysis
    // thread finished draining; report the overlap-inclusive wall time so
    // events_per_sec stays honest across pipeline modes
    outcome.stats.wall_s = t0.elapsed().as_secs_f64();
    Ok(outcome)
}

/// One-shot convenience mirroring [`super::machine::run_program`], with the
/// delivery mode as a knob: build a machine, run, return outcome and
/// machine (for post-run buffer inspection).
pub fn run_program_mode<'p>(
    prog: &'p Program,
    sink: &mut (dyn Instrument + Send),
    mode: PipelineMode,
) -> Result<(Outcome, Machine<'p>)> {
    let mut m = Machine::new(prog)?;
    let out = match mode {
        PipelineMode::Inline => m.run(sink)?,
        PipelineMode::Offload => run_offload(&mut m, sink)?,
    };
    Ok((out, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::events::Counter;
    use crate::ir::ProgramBuilder;

    fn loop_program(n: i64) -> Program {
        let mut b = ProgramBuilder::new("off");
        let a = b.alloc_f64("a", 64);
        let len = b.const_i(64);
        let trip = b.const_i(n);
        b.counted_loop(trip, |b, i| {
            let idx = b.rem(i, len);
            let v = b.load_f64(a, idx);
            let w = b.fadd(v, v);
            b.store_f64(a, idx, w);
        });
        b.finish(None)
    }

    #[test]
    fn mode_parsing_roundtrips() {
        assert_eq!(PipelineMode::from_name("inline").unwrap(), PipelineMode::Inline);
        assert_eq!(PipelineMode::from_name(" offload ").unwrap(), PipelineMode::Offload);
        assert!(PipelineMode::from_name("bogus").is_err());
        assert_eq!(PipelineMode::default().name(), "inline");
    }

    #[test]
    fn offload_counts_match_inline() {
        let p = loop_program(5000);
        let mut inline = Counter::default();
        let mut offl = Counter::default();
        let o1 = Machine::new(&p).unwrap().run(&mut inline).unwrap();
        let o2 = run_offload(&mut Machine::new(&p).unwrap(), &mut offl).unwrap();
        assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs);
        assert_eq!(o1.stats.dyn_blocks, o2.stats.dyn_blocks);
        assert_eq!(o1.stats.dyn_branches, o2.stats.dyn_branches);
        assert_eq!(
            (inline.instrs, inline.blocks, inline.branches, inline.loads, inline.stores),
            (offl.instrs, offl.blocks, offl.branches, offl.loads, offl.stores)
        );
        assert!(o2.stats.wall_s > 0.0);
        assert!(o2.stats.events_per_sec() > 0.0);
    }

    #[test]
    fn run_program_mode_selects_delivery() {
        let p = loop_program(100);
        let mut a = Counter::default();
        let mut b = Counter::default();
        let (o1, _) = run_program_mode(&p, &mut a, PipelineMode::Inline).unwrap();
        let (o2, _) = run_program_mode(&p, &mut b, PipelineMode::Offload).unwrap();
        assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs);
        assert_eq!(a.instrs, b.instrs);
    }

    #[test]
    fn interpreter_error_propagates_through_offload() {
        let mut b = ProgramBuilder::new("dz");
        let x = b.const_i(1);
        let z = b.const_i(0);
        b.div(x, z);
        let p = b.finish(None);
        let mut c = Counter::default();
        let err = run_offload(&mut Machine::new(&p).unwrap(), &mut c);
        assert!(err.is_err());
    }
}
