//! Execution engine + instrumentation event stream (PISA's run phase).

pub mod events;
pub mod machine;
pub mod memory;

pub use events::{
    Counter, EventChunk, Fanout, Instrument, InstrEvent, MemAccess, NullInstrument, TraceEvent,
    CHUNK_EVENTS,
};
pub use machine::{run_program, ExecStats, Machine, Outcome};
pub use memory::Memory;
