//! Execution engine + instrumentation event stream (PISA's run phase).

pub mod events;
pub mod machine;
pub mod memory;
pub mod offload;

pub use events::{
    adaptive_chunk_capacity, ChunkLanes, Counter, EventChunk, Fanout, Instrument, InstrEvent,
    LaneMask, MemAccess, NullInstrument, TraceEvent, CHUNK_EVENTS, MIN_CHUNK_EVENTS, TAG_BLOCK,
    TAG_BR_NOT, TAG_BR_TAKEN,
};
pub use machine::{run_program, ExecStats, Machine, Outcome};
pub use memory::Memory;
pub use offload::{
    run_offload, run_offload_supervised, run_program_mode, sharded::run_sharded,
    sharded::run_sharded_supervised, PipelineMode, PipelineRun, Workers,
};
