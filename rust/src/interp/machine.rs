//! The execution engine: runs a program concretely and emits the
//! instrumentation stream (PISA's instrumented-binary run, §II Fig 1).
//!
//! The inner loop is written once, generic over an [`EventSink`] delivery
//! strategy, and monomorphized per strategy: [`Machine::run`] batches
//! events into a reusable [`EventChunk`] flushed at block boundaries (the
//! default, fast path), [`Machine::run_per_event`] delivers one virtual
//! call per event (the reference path the chunked-equivalence property
//! test checks against, and the dispatch baseline in
//! `benches/perf_micro.rs`), and [`super::offload::run_offload`] ships
//! whole chunks to a dedicated analysis thread so interpretation and
//! analysis overlap.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::events::{EventChunk, Instrument, InstrEvent, MemAccess, TraceEvent};
use super::memory::Memory;
use crate::fault::{panic_message, ArmedFault, Deadline, FaultPlan, PanicError, Role, SuperviseOpts};
use crate::ir::{Imm, Instr, Op, Program, Terminator, Value};

/// Execution statistics returned with every run.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub dyn_instrs: u64,
    pub dyn_blocks: u64,
    pub dyn_branches: u64,
    pub mem_reads: u64,
    pub mem_writes: u64,
    /// Wall-clock seconds spent inside the run (execution + analyzers).
    pub wall_s: f64,
}

impl ExecStats {
    /// Total trace events emitted (block entries + instructions + branches).
    pub fn events(&self) -> u64 {
        self.dyn_blocks + self.dyn_instrs + self.dyn_branches
    }

    /// Events per second of wall time — the profiler throughput number the
    /// pipeline reports so perf regressions are visible in every run.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.events() as f64 / self.wall_s
        }
    }
}

/// Result of a completed run.
#[derive(Debug)]
pub struct Outcome {
    pub ret: Option<Value>,
    pub stats: ExecStats,
}

/// How the inner loop hands events to the instrumentation. Monomorphized:
/// the chunked, per-event and offloaded strategies each get their own copy
/// of the interpreter loop with no per-event indirection of their own (the
/// offload delivery lives in [`super::offload`]).
pub(crate) trait EventSink {
    fn event(&mut self, ev: TraceEvent);
    /// About to execute a block with `upcoming` instructions (+ entry and
    /// possibly a branch event). Chunked delivery flushes here when the
    /// buffer lacks headroom, so flushes land on block boundaries.
    fn block_boundary(&mut self, upcoming: usize);
    /// End of run: deliver anything still buffered.
    fn finish(&mut self);
    /// A supervision error raised at the last flush (injected fault or
    /// watchdog expiry); the interpreter loop bails with it at the next
    /// block boundary. Unsupervised sinks never raise one.
    fn take_error(&mut self) -> Option<anyhow::Error> {
        None
    }
}

/// Per-event delivery: one `on_event` virtual call per trace event.
struct PerEvent<'s> {
    sink: &'s mut dyn Instrument,
}

impl EventSink for PerEvent<'_> {
    #[inline]
    fn event(&mut self, ev: TraceEvent) {
        self.sink.on_event(&ev);
    }

    #[inline]
    fn block_boundary(&mut self, _upcoming: usize) {}

    fn finish(&mut self) {}
}

/// Chunked delivery: events accumulate in a reusable fixed-capacity buffer
/// and reach the instrumentation as `on_chunk` slices. Carries the inline
/// supervision state — with inline delivery every pipeline thread
/// collapses onto the interpreter, so all fault sites and the watchdog
/// fire here, at the same chunk boundaries the off-thread paths use.
struct Chunked<'s> {
    sink: &'s mut dyn Instrument,
    chunk: EventChunk,
    armed: ArmedFault,
    deadline: Deadline,
    error: Option<anyhow::Error>,
}

impl<'s> Chunked<'s> {
    fn new(sink: &'s mut dyn Instrument, chunk: EventChunk) -> Self {
        Chunked {
            sink,
            chunk,
            armed: FaultPlan::none().arm(&[]),
            deadline: Deadline::none(),
            error: None,
        }
    }

    fn flush(&mut self) {
        if self.error.is_none() {
            if let Err(e) = self.armed.tick() {
                self.error = Some(e.into());
            } else if let Err(e) = self.deadline.check() {
                self.error = Some(e.into());
            }
        }
        self.chunk.flush_into(self.sink);
    }
}

impl EventSink for Chunked<'_> {
    #[inline]
    fn event(&mut self, ev: TraceEvent) {
        // the boundary check keeps headroom for a whole block; a single
        // block larger than the buffer still flushes safely mid-block
        if self.chunk.is_full() {
            self.flush();
        }
        self.chunk.push(ev);
    }

    #[inline]
    fn block_boundary(&mut self, upcoming: usize) {
        if self.chunk.needs_flush_for_block(upcoming) {
            self.flush();
        }
    }

    fn finish(&mut self) {
        self.flush();
    }

    fn take_error(&mut self) -> Option<anyhow::Error> {
        self.error.take()
    }
}

/// A loaded program plus its memory image. Keeping the machine around after
/// `run` lets workloads validate output buffers against native oracles.
pub struct Machine<'p> {
    prog: &'p Program,
    pub mem: Memory,
    regs: Vec<Value>,
    /// Hard cap on dynamic instructions — a malformed workload must not hang
    /// the profiling pipeline.
    pub instr_limit: u64,
}

impl<'p> Machine<'p> {
    pub fn new(prog: &'p Program) -> Result<Self> {
        let mem = Memory::new(prog.mem_bytes, &prog.data)?;
        Ok(Machine {
            prog,
            mem,
            regs: vec![Value::I(0); prog.func.n_regs as usize],
            instr_limit: 2_000_000_000,
        })
    }

    #[inline]
    fn reg(&self, r: u16) -> Value {
        self.regs[r as usize]
    }

    /// Chunk capacity the chunked and offloaded paths use for this
    /// program — see [`super::events::adaptive_chunk_capacity`].
    pub fn chunk_capacity(&self) -> usize {
        super::events::adaptive_chunk_capacity(self.prog)
    }

    /// Execute to completion, streaming events into `sink` in chunks (the
    /// default profiling path). Chunk capacity adapts to the program's
    /// static block shape.
    pub fn run(&mut self, sink: &mut dyn Instrument) -> Result<Outcome> {
        let chunk = EventChunk::with_capacity(self.chunk_capacity());
        let mut delivery = Chunked::new(sink, chunk);
        self.run_with(&mut delivery)
    }

    /// [`Machine::run`] under supervision: the fault plan is armed for
    /// every role (inline delivery does all the pipeline's work on this
    /// thread) and the watchdog deadline is checked at chunk boundaries.
    /// An injected panic is caught here and surfaced as a typed
    /// [`PanicError`] instead of unwinding the caller. With empty
    /// `SuperviseOpts` this is bit-identical to [`Machine::run`].
    pub fn run_supervised(
        &mut self,
        sink: &mut dyn Instrument,
        sup: SuperviseOpts,
    ) -> Result<Outcome> {
        let chunk = EventChunk::with_capacity(self.chunk_capacity());
        let mut delivery = Chunked::new(sink, chunk);
        delivery.armed = sup.fault.arm(&[Role::Interp, Role::Broadcaster, Role::AnyWorker]);
        delivery.deadline = sup.deadline();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.run_with(&mut delivery)
        }));
        match run {
            Ok(res) => res,
            Err(payload) => Err(PanicError::new("interp", panic_message(payload)).into()),
        }
    }

    /// Execute to completion with one `on_event` call per trace event — the
    /// un-batched reference path. Metrics computed over either path are
    /// bit-identical (see `rust/tests/prop_chunked.rs`).
    pub fn run_per_event(&mut self, sink: &mut dyn Instrument) -> Result<Outcome> {
        let mut delivery = PerEvent { sink };
        self.run_with(&mut delivery)
    }

    /// Execute one instruction: compute, write the destination register,
    /// and report the memory access (if any) for the event stream.
    #[inline(always)]
    fn exec_instr(&mut self, ins: &Instr, stats: &mut ExecStats) -> Result<Option<MemAccess>> {
        let s = ins.sources();
        let mut mem_ev: Option<MemAccess> = None;
        let result: Option<Value> = match ins.op {
            Op::ConstI => match ins.imm {
                Imm::I(v) => Some(Value::I(v)),
                _ => bail!("consti without int imm"),
            },
            Op::ConstF => match ins.imm {
                Imm::F(v) => Some(Value::F(v)),
                _ => bail!("constf without float imm"),
            },
            Op::Mov => Some(self.reg(s[0])),
            Op::Select => Some(if self.reg(s[0]).truthy() {
                self.reg(s[1])
            } else {
                self.reg(s[2])
            }),
            Op::Add => Some(Value::I(self.reg(s[0]).as_i().wrapping_add(self.reg(s[1]).as_i()))),
            Op::Sub => Some(Value::I(self.reg(s[0]).as_i().wrapping_sub(self.reg(s[1]).as_i()))),
            Op::Mul => Some(Value::I(self.reg(s[0]).as_i().wrapping_mul(self.reg(s[1]).as_i()))),
            Op::Div => {
                let d = self.reg(s[1]).as_i();
                if d == 0 {
                    bail!("integer division by zero in {}", self.prog.func.name);
                }
                Some(Value::I(self.reg(s[0]).as_i().wrapping_div(d)))
            }
            Op::Rem => {
                let d = self.reg(s[1]).as_i();
                if d == 0 {
                    bail!("integer remainder by zero in {}", self.prog.func.name);
                }
                Some(Value::I(self.reg(s[0]).as_i().wrapping_rem(d)))
            }
            Op::And => Some(Value::I(self.reg(s[0]).as_i() & self.reg(s[1]).as_i())),
            Op::Or => Some(Value::I(self.reg(s[0]).as_i() | self.reg(s[1]).as_i())),
            Op::Xor => Some(Value::I(self.reg(s[0]).as_i() ^ self.reg(s[1]).as_i())),
            Op::Shl => Some(Value::I(
                self.reg(s[0]).as_i().wrapping_shl(self.reg(s[1]).as_i() as u32),
            )),
            Op::Shr => Some(Value::I(
                (self.reg(s[0]).as_i() as u64).wrapping_shr(self.reg(s[1]).as_i() as u32) as i64,
            )),
            Op::FAdd => Some(Value::F(self.reg(s[0]).as_f() + self.reg(s[1]).as_f())),
            Op::FSub => Some(Value::F(self.reg(s[0]).as_f() - self.reg(s[1]).as_f())),
            Op::FMul => Some(Value::F(self.reg(s[0]).as_f() * self.reg(s[1]).as_f())),
            Op::FDiv => Some(Value::F(self.reg(s[0]).as_f() / self.reg(s[1]).as_f())),
            Op::FNeg => Some(Value::F(-self.reg(s[0]).as_f())),
            Op::FSqrt => Some(Value::F(self.reg(s[0]).as_f().sqrt())),
            Op::FExp => Some(Value::F(self.reg(s[0]).as_f().exp())),
            Op::FAbs => Some(Value::F(self.reg(s[0]).as_f().abs())),
            Op::FMin => Some(Value::F(self.reg(s[0]).as_f().min(self.reg(s[1]).as_f()))),
            Op::FMax => Some(Value::F(self.reg(s[0]).as_f().max(self.reg(s[1]).as_f()))),
            Op::IToF => Some(Value::F(self.reg(s[0]).as_i() as f64)),
            Op::FToI => Some(Value::I(self.reg(s[0]).as_f() as i64)),
            Op::CmpEq => Some(Value::I((self.reg(s[0]).as_i() == self.reg(s[1]).as_i()) as i64)),
            Op::CmpNe => Some(Value::I((self.reg(s[0]).as_i() != self.reg(s[1]).as_i()) as i64)),
            Op::CmpLt => Some(Value::I((self.reg(s[0]).as_i() < self.reg(s[1]).as_i()) as i64)),
            Op::CmpLe => Some(Value::I((self.reg(s[0]).as_i() <= self.reg(s[1]).as_i()) as i64)),
            Op::CmpGt => Some(Value::I((self.reg(s[0]).as_i() > self.reg(s[1]).as_i()) as i64)),
            Op::CmpGe => Some(Value::I((self.reg(s[0]).as_i() >= self.reg(s[1]).as_i()) as i64)),
            Op::FCmpEq => Some(Value::I((self.reg(s[0]).as_f() == self.reg(s[1]).as_f()) as i64)),
            Op::FCmpLt => Some(Value::I((self.reg(s[0]).as_f() < self.reg(s[1]).as_f()) as i64)),
            Op::FCmpLe => Some(Value::I((self.reg(s[0]).as_f() <= self.reg(s[1]).as_f()) as i64)),
            Op::FCmpGt => Some(Value::I((self.reg(s[0]).as_f() > self.reg(s[1]).as_f()) as i64)),
            Op::Load => {
                let addr = self.reg(s[0]).as_i() as u64;
                let raw = self.mem.load(addr, ins.size)?;
                stats.mem_reads += 1;
                mem_ev = Some(MemAccess { addr, size: ins.size, is_store: false });
                Some(if ins.size == 8 && ins.fp {
                    Value::F(f64::from_bits(raw))
                } else {
                    Value::I(raw as i64)
                })
            }
            Op::Store => {
                let addr = self.reg(s[1]).as_i() as u64;
                let raw = match self.reg(s[0]) {
                    Value::F(v) if ins.size == 8 && ins.fp => v.to_bits(),
                    Value::F(v) if !ins.fp => (v as i64) as u64,
                    v => v.as_i() as u64,
                };
                self.mem.store(addr, ins.size, raw)?;
                stats.mem_writes += 1;
                mem_ev = Some(MemAccess { addr, size: ins.size, is_store: true });
                None
            }
        };
        if let (Some(d), Some(v)) = (ins.dst, result) {
            self.regs[d as usize] = v;
        }
        Ok(mem_ev)
    }

    /// Begin a resumable run: block cursor at the entry block, fresh stats,
    /// wall clock started. Drive it with [`Machine::step_block`] (see
    /// [`crate::trace::InterpSource`]) or let [`Machine::run_with`] loop it
    /// to completion.
    pub(crate) fn start(&self) -> StepState {
        StepState {
            bb: 0,
            stats: ExecStats::default(),
            t0: Instant::now(),
            done: false,
            ret: None,
        }
    }

    /// Instruction count of the block the cursor points at — the value the
    /// chunked sinks' `block_boundary` flush policy consults *before* the
    /// block executes. Errors on a dangling block id, exactly where the
    /// monolithic loop used to.
    pub(crate) fn upcoming(&self, st: &StepState) -> Result<usize> {
        let bb = st.bb;
        let block = self
            .prog
            .func
            .blocks
            .get(bb as usize)
            .with_context(|| format!("bad block id {bb}"))?;
        Ok(block.instrs.len())
    }

    /// Execute exactly one basic block (entry event, instructions,
    /// terminator) and advance the cursor. On `Ret` the state is marked
    /// done and carries the return value; the caller owns end-of-run
    /// delivery (`finish`) and the wall-clock stamp, so pull-based drivers
    /// can interleave their own chunk handling between blocks.
    pub(crate) fn step_block<S: EventSink>(
        &mut self,
        st: &mut StepState,
        delivery: &mut S,
    ) -> Result<()> {
        let prog: &'p Program = self.prog;
        let bb = st.bb;
        let block = prog
            .func
            .blocks
            .get(bb as usize)
            .with_context(|| format!("bad block id {bb}"))?;
        st.stats.dyn_blocks += 1;
        delivery.event(TraceEvent::BlockEnter { block: bb });

        for ins in &block.instrs {
            st.stats.dyn_instrs += 1;
            if st.stats.dyn_instrs > self.instr_limit {
                bail!(
                    "instruction limit exceeded ({}) in {}",
                    self.instr_limit,
                    self.prog.func.name
                );
            }
            let mem_ev = self.exec_instr(ins, &mut st.stats)?;
            delivery.event(TraceEvent::Instr(InstrEvent {
                op: ins.op,
                dst: ins.dst,
                srcs: ins.srcs,
                n_srcs: ins.n_srcs,
                mem: mem_ev,
                block: bb,
            }));
        }

        match &block.term {
            Terminator::Jmp(t) => st.bb = *t,
            Terminator::Br { cond, then_, else_ } => {
                let taken = self.reg(*cond).truthy();
                st.stats.dyn_branches += 1;
                delivery.event(TraceEvent::Branch { block: bb, taken });
                st.bb = if taken { *then_ } else { *else_ };
            }
            Terminator::Ret(r) => {
                st.ret = r.map(|r| self.reg(r));
                st.done = true;
            }
        }
        Ok(())
    }

    /// The interpreter loop, generic over the event-delivery strategy: the
    /// resumable stepper driven to completion. Event order, error order and
    /// the wall-clock stamp are identical to the historical monolithic
    /// loop (the bit-identity tests in `prop_chunked.rs` pin this).
    pub(crate) fn run_with<S: EventSink>(&mut self, delivery: &mut S) -> Result<Outcome> {
        let mut st = self.start();
        while !st.done {
            delivery.block_boundary(self.upcoming(&st)?);
            if let Some(e) = delivery.take_error() {
                // a supervision fault (injected error, watchdog expiry)
                // raised at the flush — bail on the block boundary
                return Err(e);
            }
            self.step_block(&mut st, delivery)?;
        }
        delivery.finish();
        if let Some(e) = delivery.take_error() {
            return Err(e);
        }
        st.stats.wall_s = st.t0.elapsed().as_secs_f64();
        Ok(Outcome { ret: st.ret, stats: st.stats })
    }
}

/// Resumable interpreter cursor: the block program counter plus the run
/// statistics accumulated so far. Produced by [`Machine::start`], advanced
/// one block at a time by [`Machine::step_block`]. The pull-based
/// [`crate::trace::InterpSource`] adapter holds one of these to fill
/// [`EventChunk`]s on demand.
pub(crate) struct StepState {
    bb: u32,
    pub(crate) stats: ExecStats,
    t0: Instant,
    pub(crate) done: bool,
    ret: Option<Value>,
}

/// One-shot convenience: build a machine, run (chunked delivery), return
/// outcome and machine (for post-run buffer inspection).
pub fn run_program<'p>(
    prog: &'p Program,
    sink: &mut dyn Instrument,
) -> Result<(Outcome, Machine<'p>)> {
    let mut m = Machine::new(prog)?;
    let out = m.run(sink)?;
    Ok((out, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::events::{Counter, NullInstrument};
    use crate::ir::ProgramBuilder;

    #[test]
    fn arithmetic_and_return() {
        let mut b = ProgramBuilder::new("t");
        let x = b.const_f(2.0);
        let y = b.const_f(0.25);
        let z = b.fdiv(x, y); // 8.0
        let w = b.fsqrt(z); // ~2.828
        let p = b.finish(Some(w));
        let mut sink = NullInstrument;
        let (out, _) = run_program(&p, &mut sink).unwrap();
        let v = match out.ret.unwrap() {
            Value::F(v) => v,
            _ => panic!(),
        };
        assert!((v - 8.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn loop_sums_array() {
        let data: Vec<f64> = (1..=10).map(|v| v as f64).collect();
        let mut b = ProgramBuilder::new("sum");
        let a = b.alloc_f64_init("a", &data);
        let acc = b.const_f(0.0);
        let n = b.const_i(10);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let s = b.fadd(acc, v);
            b.assign(acc, s);
        });
        let p = b.finish(Some(acc));
        let mut c = Counter::default();
        let (out, _) = run_program(&p, &mut c).unwrap();
        assert_eq!(out.ret.unwrap().as_f(), 55.0);
        assert_eq!(c.loads, 10);
        assert_eq!(out.stats.dyn_branches, 11); // 10 taken + 1 exit
        assert_eq!(c.instrs + c.blocks + c.branches, out.stats.events());
    }

    #[test]
    fn chunked_and_per_event_counts_agree() {
        let mut b = ProgramBuilder::new("eq");
        let a = b.alloc_f64("a", 256);
        let n = b.const_i(256);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(a, i);
            let w = b.fadd(v, v);
            b.store_f64(a, i, w);
        });
        let p = b.finish(None);
        let mut chunked = Counter::default();
        let mut per_event = Counter::default();
        let o1 = Machine::new(&p).unwrap().run(&mut chunked).unwrap();
        let o2 = Machine::new(&p).unwrap().run_per_event(&mut per_event).unwrap();
        assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs);
        assert_eq!(o1.stats.dyn_blocks, o2.stats.dyn_blocks);
        assert_eq!(o1.stats.dyn_branches, o2.stats.dyn_branches);
        assert_eq!(
            (chunked.instrs, chunked.blocks, chunked.branches, chunked.loads, chunked.stores),
            (
                per_event.instrs,
                per_event.blocks,
                per_event.branches,
                per_event.loads,
                per_event.stores
            )
        );
        assert!(o1.stats.wall_s > 0.0);
        assert!(o1.stats.events_per_sec() > 0.0);
    }

    #[test]
    fn store_then_load_roundtrips() {
        let mut b = ProgramBuilder::new("rw");
        let a = b.alloc_f64("a", 4);
        let idx = b.const_i(2);
        let v = b.const_f(9.5);
        b.store_f64(a, idx, v);
        let r = b.load_f64(a, idx);
        let p = b.finish(Some(r));
        let (out, m) = run_program(&p, &mut NullInstrument).unwrap();
        assert_eq!(out.ret.unwrap().as_f(), 9.5);
        let buf = p.buffer("a").unwrap();
        assert_eq!(m.mem.read_f64_slice(buf.base, 4).unwrap()[2], 9.5);
    }

    #[test]
    fn if_then_else_takes_right_arm() {
        let mut b = ProgramBuilder::new("sel");
        let out_buf = b.alloc_f64("o", 1);
        let one = b.const_i(1);
        let two = b.const_i(2);
        let c = b.cmp_lt(two, one); // false
        let zero = b.const_i(0);
        b.if_then_else(
            c,
            |b| {
                let v = b.const_f(111.0);
                b.store_f64(out_buf, zero, v);
            },
            |b| {
                let v = b.const_f(222.0);
                b.store_f64(out_buf, zero, v);
            },
        );
        let p = b.finish(None);
        let (_, m) = run_program(&p, &mut NullInstrument).unwrap();
        assert_eq!(m.mem.load_f64(p.buffer("o").unwrap().base).unwrap(), 222.0);
    }

    #[test]
    fn instr_limit_guards_infinite_loop() {
        let mut b = ProgramBuilder::new("inf");
        b.while_loop(|b| b.const_i(1), |b| {
            b.const_i(42);
        });
        let p = b.finish(None);
        let mut m = Machine::new(&p).unwrap();
        m.instr_limit = 10_000;
        assert!(m.run(&mut NullInstrument).is_err());
    }

    #[test]
    fn division_by_zero_is_error_not_panic() {
        let mut b = ProgramBuilder::new("dz");
        let x = b.const_i(1);
        let z = b.const_i(0);
        b.div(x, z);
        let p = b.finish(None);
        assert!(run_program(&p, &mut NullInstrument).is_err());
    }
}
