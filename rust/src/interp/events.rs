//! The instrumentation event stream — PISA's analysis-library call interface.
//!
//! In PISA, an LLVM pass inserts calls to an external analysis library before
//! every IR instruction; here the execution engine emits one [`TraceEvent`]
//! per dynamic instruction / block entry / conditional branch, and analyzers
//! implement [`Instrument`]. Events are plain `Copy` data so they can also be
//! batched over a channel to worker threads (see `coordinator::pipeline`).

use crate::ir::{BlockId, Op, Reg};

/// One dynamic memory access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    pub addr: u64,
    pub size: u8,
    pub is_store: bool,
}

/// One executed instruction, with enough operand structure for dependency
/// analyses (ILP/DLP/BBLP) to rebuild the dataflow graph on the fly.
#[derive(Debug, Clone, Copy)]
pub struct InstrEvent {
    pub op: Op,
    pub dst: Option<Reg>,
    pub srcs: [Reg; 3],
    pub n_srcs: u8,
    pub mem: Option<MemAccess>,
    /// Static basic block the instruction belongs to.
    pub block: BlockId,
}

impl InstrEvent {
    pub fn sources(&self) -> &[Reg] {
        &self.srcs[..self.n_srcs as usize]
    }
}

/// The dynamic trace alphabet.
#[derive(Debug, Clone, Copy)]
pub enum TraceEvent {
    /// Control entered a basic block (one per dynamic BB instance).
    BlockEnter { block: BlockId },
    /// One executed instruction.
    Instr(InstrEvent),
    /// A *conditional* branch resolved. `block` identifies the static branch
    /// site (the block it terminates).
    Branch { block: BlockId, taken: bool },
}

/// Analyzer interface. `on_event` is the hot path — called once per dynamic
/// event; implementations must not allocate per call on common paths.
pub trait Instrument {
    fn on_event(&mut self, ev: &TraceEvent);
}

/// No-op sink (pure execution, oracle validation runs).
pub struct NullInstrument;

impl Instrument for NullInstrument {
    #[inline]
    fn on_event(&mut self, _ev: &TraceEvent) {}
}

/// Fan-out to several analyzers in one pass over the trace.
pub struct Fanout<'a> {
    pub sinks: Vec<&'a mut dyn Instrument>,
}

impl<'a> Fanout<'a> {
    pub fn new(sinks: Vec<&'a mut dyn Instrument>) -> Self {
        Fanout { sinks }
    }
}

impl Instrument for Fanout<'_> {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        for s in self.sinks.iter_mut() {
            s.on_event(ev);
        }
    }
}

/// Event counter (tests, quick stats).
#[derive(Default, Debug, Clone)]
pub struct Counter {
    pub instrs: u64,
    pub blocks: u64,
    pub branches: u64,
    pub loads: u64,
    pub stores: u64,
}

impl Instrument for Counter {
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::BlockEnter { .. } => self.blocks += 1,
            TraceEvent::Branch { .. } => self.branches += 1,
            TraceEvent::Instr(i) => {
                self.instrs += 1;
                if let Some(m) = i.mem {
                    if m.is_store {
                        self.stores += 1;
                    } else {
                        self.loads += 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instr_ev(op: Op) -> TraceEvent {
        TraceEvent::Instr(InstrEvent {
            op,
            dst: Some(0),
            srcs: [0; 3],
            n_srcs: 0,
            mem: None,
            block: 0,
        })
    }

    #[test]
    fn counter_counts() {
        let mut c = Counter::default();
        c.on_event(&TraceEvent::BlockEnter { block: 0 });
        c.on_event(&instr_ev(Op::ConstI));
        c.on_event(&TraceEvent::Instr(InstrEvent {
            op: Op::Load,
            dst: Some(1),
            srcs: [0; 3],
            n_srcs: 1,
            mem: Some(MemAccess { addr: 64, size: 8, is_store: false }),
            block: 0,
        }));
        c.on_event(&TraceEvent::Branch { block: 0, taken: true });
        assert_eq!((c.blocks, c.instrs, c.loads, c.branches), (1, 2, 1, 1));
    }

    #[test]
    fn fanout_reaches_all() {
        let mut a = Counter::default();
        let mut b = Counter::default();
        {
            let mut f = Fanout::new(vec![&mut a, &mut b]);
            f.on_event(&instr_ev(Op::Add));
        }
        assert_eq!(a.instrs, 1);
        assert_eq!(b.instrs, 1);
    }
}
