//! The instrumentation event stream — PISA's analysis-library call interface.
//!
//! In PISA, an LLVM pass inserts calls to an external analysis library before
//! every IR instruction; here the execution engine emits one [`TraceEvent`]
//! per dynamic instruction / block entry / conditional branch, and analyzers
//! implement [`Instrument`].
//!
//! ## Chunked delivery (the hot path)
//!
//! Events are not handed to analyzers one virtual call at a time. The
//! interpreter accumulates them into a reusable fixed-capacity
//! [`EventChunk`] (~4K events) and flushes the whole slice through
//! [`Instrument::on_chunk`] at block boundaries (or when the buffer fills
//! inside a degenerate giant block) and at end-of-run. One virtual call
//! then amortizes over thousands of events, and each analyzer iterates a
//! cache-resident slice with statically-dispatched per-event handling —
//! the batched-trace-processing structure NMPO uses to keep profiling
//! overhead sane at realistic workload sizes.
//!
//! `on_event` remains as the un-batched reference path: the default
//! `on_chunk` simply loops over it, so an analyzer only implements the
//! chunk form when it has per-chunk state worth hoisting. Event order is
//! identical on both paths, and every analyzer is a pure fold over the
//! event sequence, so chunked and per-event execution produce bit-identical
//! metrics (enforced by `rust/tests/prop_chunked.rs`).
//!
//! Events are plain `Copy` data so chunks can also be batched over a
//! channel to worker threads (see `coordinator::pipeline`).

use crate::ir::{BlockId, Op, Reg};

/// One dynamic memory access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    pub addr: u64,
    pub size: u8,
    pub is_store: bool,
}

/// One executed instruction, with enough operand structure for dependency
/// analyses (ILP/DLP/BBLP) to rebuild the dataflow graph on the fly.
#[derive(Debug, Clone, Copy)]
pub struct InstrEvent {
    pub op: Op,
    pub dst: Option<Reg>,
    pub srcs: [Reg; 3],
    pub n_srcs: u8,
    pub mem: Option<MemAccess>,
    /// Static basic block the instruction belongs to.
    pub block: BlockId,
}

impl InstrEvent {
    pub fn sources(&self) -> &[Reg] {
        &self.srcs[..self.n_srcs as usize]
    }
}

/// The dynamic trace alphabet.
#[derive(Debug, Clone, Copy)]
pub enum TraceEvent {
    /// Control entered a basic block (one per dynamic BB instance).
    BlockEnter { block: BlockId },
    /// One executed instruction.
    Instr(InstrEvent),
    /// A *conditional* branch resolved. `block` identifies the static branch
    /// site (the block it terminates).
    Branch { block: BlockId, taken: bool },
}

/// Default capacity of the interpreter's event buffer: large enough to
/// amortize the per-chunk virtual call to nothing, small enough that a
/// chunk of 16-byte events stays L2-resident next to the analyzer state.
pub const CHUNK_EVENTS: usize = 4096;

/// Reusable fixed-capacity event buffer. The interpreter owns exactly one
/// and recycles its allocation for the whole run; `flush_into` hands the
/// buffered slice to a sink and clears it.
#[derive(Debug, Clone)]
pub struct EventChunk {
    buf: Vec<TraceEvent>,
    capacity: usize,
}

impl Default for EventChunk {
    fn default() -> Self {
        Self::new()
    }
}

impl EventChunk {
    pub fn new() -> Self {
        Self::with_capacity(CHUNK_EVENTS)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        EventChunk { buf: Vec::with_capacity(capacity), capacity }
    }

    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        debug_assert!(self.buf.len() < self.capacity);
        self.buf.push(ev);
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.buf.len() >= self.capacity
    }

    /// Free slots before the buffer must be flushed.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.capacity - self.buf.len()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.buf
    }

    /// Hand the buffered events to `sink` in one `on_chunk` call and reset
    /// the buffer (allocation retained).
    #[inline]
    pub fn flush_into(&mut self, sink: &mut dyn Instrument) {
        if !self.buf.is_empty() {
            sink.on_chunk(&self.buf);
            self.buf.clear();
        }
    }
}

/// Analyzer interface.
///
/// `on_chunk` is the hot path: the interpreter delivers events in chunks
/// (see [`EventChunk`]), so a `dyn Instrument` costs one virtual call per
/// chunk instead of one per event, and the default implementation's
/// `on_event` calls are statically dispatched and inlinable. `on_event` is
/// the per-event reference semantics; implementations must not allocate per
/// call on common paths, and overridden `on_chunk`s must fold the slice in
/// order, exactly as the default does.
pub trait Instrument {
    fn on_event(&mut self, ev: &TraceEvent);

    /// Consume a batch of events in trace order. Override to hoist
    /// per-chunk state; must be observationally identical to calling
    /// `on_event` on each element in order.
    #[inline]
    fn on_chunk(&mut self, events: &[TraceEvent]) {
        for ev in events {
            self.on_event(ev);
        }
    }
}

/// No-op sink (pure execution, oracle validation runs).
pub struct NullInstrument;

impl Instrument for NullInstrument {
    #[inline]
    fn on_event(&mut self, _ev: &TraceEvent) {}

    #[inline]
    fn on_chunk(&mut self, _events: &[TraceEvent]) {}
}

/// Fan-out to several analyzers in one pass over the trace.
///
/// Retained for ad-hoc sink composition and as the per-event dispatch
/// baseline in `benches/perf_micro.rs`; the profiling pipeline itself now
/// composes analyzers through `analysis::AnalyzerStack`, which fans chunks
/// out with static dispatch per analyzer.
pub struct Fanout<'a> {
    pub sinks: Vec<&'a mut dyn Instrument>,
}

impl<'a> Fanout<'a> {
    pub fn new(sinks: Vec<&'a mut dyn Instrument>) -> Self {
        Fanout { sinks }
    }
}

impl Instrument for Fanout<'_> {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        for s in self.sinks.iter_mut() {
            s.on_event(ev);
        }
    }

    #[inline]
    fn on_chunk(&mut self, events: &[TraceEvent]) {
        for s in self.sinks.iter_mut() {
            s.on_chunk(events);
        }
    }
}

/// Event counter (tests, quick stats). Chunk delivery uses the default
/// `on_chunk` loop — nothing worth hoisting here.
#[derive(Default, Debug, Clone)]
pub struct Counter {
    pub instrs: u64,
    pub blocks: u64,
    pub branches: u64,
    pub loads: u64,
    pub stores: u64,
}

impl Instrument for Counter {
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::BlockEnter { .. } => self.blocks += 1,
            TraceEvent::Branch { .. } => self.branches += 1,
            TraceEvent::Instr(i) => {
                self.instrs += 1;
                if let Some(m) = i.mem {
                    if m.is_store {
                        self.stores += 1;
                    } else {
                        self.loads += 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instr_ev(op: Op) -> TraceEvent {
        TraceEvent::Instr(InstrEvent {
            op,
            dst: Some(0),
            srcs: [0; 3],
            n_srcs: 0,
            mem: None,
            block: 0,
        })
    }

    #[test]
    fn counter_counts() {
        let mut c = Counter::default();
        c.on_event(&TraceEvent::BlockEnter { block: 0 });
        c.on_event(&instr_ev(Op::ConstI));
        c.on_event(&TraceEvent::Instr(InstrEvent {
            op: Op::Load,
            dst: Some(1),
            srcs: [0; 3],
            n_srcs: 1,
            mem: Some(MemAccess { addr: 64, size: 8, is_store: false }),
            block: 0,
        }));
        c.on_event(&TraceEvent::Branch { block: 0, taken: true });
        assert_eq!((c.blocks, c.instrs, c.loads, c.branches), (1, 2, 1, 1));
    }

    #[test]
    fn counter_chunk_matches_per_event() {
        let events = vec![
            TraceEvent::BlockEnter { block: 0 },
            instr_ev(Op::ConstI),
            TraceEvent::Instr(InstrEvent {
                op: Op::Store,
                dst: None,
                srcs: [0; 3],
                n_srcs: 2,
                mem: Some(MemAccess { addr: 8, size: 8, is_store: true }),
                block: 0,
            }),
            TraceEvent::Branch { block: 0, taken: false },
        ];
        let mut a = Counter::default();
        let mut b = Counter::default();
        for ev in &events {
            a.on_event(ev);
        }
        b.on_chunk(&events);
        assert_eq!(
            (a.instrs, a.blocks, a.branches, a.loads, a.stores),
            (b.instrs, b.blocks, b.branches, b.loads, b.stores)
        );
    }

    #[test]
    fn fanout_reaches_all() {
        let mut a = Counter::default();
        let mut b = Counter::default();
        {
            let mut f = Fanout::new(vec![&mut a, &mut b]);
            f.on_event(&instr_ev(Op::Add));
        }
        assert_eq!(a.instrs, 1);
        assert_eq!(b.instrs, 1);
    }

    #[test]
    fn chunk_flushes_and_recycles() {
        let mut ch = EventChunk::with_capacity(4);
        assert!(ch.is_empty());
        for _ in 0..4 {
            ch.push(instr_ev(Op::Add));
        }
        assert!(ch.is_full());
        assert_eq!(ch.remaining(), 0);
        let mut c = Counter::default();
        ch.flush_into(&mut c);
        assert!(ch.is_empty());
        assert_eq!(c.instrs, 4);
        // flushing an empty chunk is a no-op (no zero-length on_chunk call)
        ch.flush_into(&mut c);
        assert_eq!(c.instrs, 4);
    }
}
