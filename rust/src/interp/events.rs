//! The instrumentation event stream — PISA's analysis-library call interface.
//!
//! In PISA, an LLVM pass inserts calls to an external analysis library before
//! every IR instruction; here the execution engine emits one [`TraceEvent`]
//! per dynamic instruction / block entry / conditional branch, and analyzers
//! implement [`Instrument`].
//!
//! ## Chunked delivery (the hot path)
//!
//! Events are not handed to analyzers one virtual call at a time. The
//! interpreter accumulates them into a reusable fixed-capacity
//! [`EventChunk`] and flushes the whole slice through
//! [`Instrument::on_chunk_lanes`] / [`Instrument::on_chunk`] at block
//! boundaries (or when the buffer fills inside a degenerate giant block)
//! and at end-of-run. One virtual call then amortizes over thousands of
//! events — the batched-trace-processing structure NMPO uses to keep
//! profiling overhead sane at realistic workload sizes. Chunk capacity is
//! picked per program by [`adaptive_chunk_capacity`]: branchy codes get
//! small chunks (bounded per-chunk analyzer latency), streaming kernels the
//! full [`CHUNK_EVENTS`] buffer.
//!
//! ## SoA lanes
//!
//! Most memory-side analyzers need only a dense view of the chunk — the
//! packed addresses, or one opcode tag per event — not the full 3-variant
//! enum. [`ChunkLanes`] is that structure-of-arrays view: built **once per
//! chunk** by [`EventChunk::flush_into`] (and only when the sink reports
//! [`Instrument::wants_lanes`]), then shared by every lane-capable analyzer
//! through [`Instrument::on_chunk_lanes`]. `reuse`, `mem_entropy`, `mix`
//! and `traffic` (and `spatial`, which derives from `reuse`) sweep these
//! dense lanes and never match `TraceEvent` per event on the hot path. The
//! flush builds only the lanes the sink's [`Instrument::lane_needs`]
//! [`LaneMask`] actually reads, so subset runs (`--metrics mix` →
//! tags-only; `reuse`/`mem_entropy` → addrs-only; sizes + store bitset
//! only when `traffic` is enabled) skip unread lanes entirely.
//!
//! `on_event` remains as the un-batched reference path: the default
//! `on_chunk` simply loops over it, and the default `on_chunk_lanes`
//! ignores the lanes and falls back to `on_chunk`. Event order is identical
//! on every path, and every analyzer is a pure fold over the event
//! sequence, so per-event, chunked and lane-swept execution produce
//! bit-identical metrics (enforced by `rust/tests/prop_chunked.rs`).
//!
//! ## Threading
//!
//! Events are plain `Copy` data and chunks are owned buffers, so whole
//! `EventChunk`s can cross a channel to a dedicated analysis thread — see
//! [`crate::interp::offload`], which cycles a small pool of owned chunks
//! between the interpreter and an analysis worker so interpretation and
//! analysis overlap. Each chunk carries its own lanes scratch, so the lane
//! build happens on the analysis thread, off the interpreter's critical
//! path.

use crate::ir::{BlockId, Op, Program, Reg};

/// One dynamic memory access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    pub addr: u64,
    pub size: u8,
    pub is_store: bool,
}

/// One executed instruction, with enough operand structure for dependency
/// analyses (ILP/DLP/BBLP) to rebuild the dataflow graph on the fly.
#[derive(Debug, Clone, Copy)]
pub struct InstrEvent {
    pub op: Op,
    pub dst: Option<Reg>,
    pub srcs: [Reg; 3],
    pub n_srcs: u8,
    pub mem: Option<MemAccess>,
    /// Static basic block the instruction belongs to.
    pub block: BlockId,
}

impl InstrEvent {
    pub fn sources(&self) -> &[Reg] {
        &self.srcs[..self.n_srcs as usize]
    }
}

/// The dynamic trace alphabet.
#[derive(Debug, Clone, Copy)]
pub enum TraceEvent {
    /// Control entered a basic block (one per dynamic BB instance).
    BlockEnter { block: BlockId },
    /// One executed instruction.
    Instr(InstrEvent),
    /// A *conditional* branch resolved. `block` identifies the static branch
    /// site (the block it terminates).
    Branch { block: BlockId, taken: bool },
}

/// Default (maximum) capacity of the interpreter's event buffer: large
/// enough to amortize the per-chunk virtual call to nothing, small enough
/// that a chunk of 16-byte events stays L2-resident next to the analyzer
/// state.
pub const CHUNK_EVENTS: usize = 4096;

/// Floor for [`adaptive_chunk_capacity`]: below this the per-chunk call
/// overhead starts to show again.
pub const MIN_CHUNK_EVENTS: usize = 512;

/// Pick an [`EventChunk`] capacity for `prog` from its static shape: the
/// mean block length (in events: instructions + block entry + a possible
/// branch) times a ~64-block-instance budget, rounded to a power of two and
/// clamped to `[MIN_CHUNK_EVENTS, CHUNK_EVENTS]`.
///
/// Branchy programs (short blocks) flush small chunks, which bounds the
/// latency an offloaded analyzer adds behind the interpreter before
/// backpressure kicks in; streaming kernels (long straight-line blocks)
/// keep the full buffer for maximum batching.
pub fn adaptive_chunk_capacity(prog: &Program) -> usize {
    let blocks = prog.func.blocks.len().max(1);
    let block_events = prog.func.static_instrs() / blocks + 2;
    (block_events * 64)
        .next_power_of_two()
        .clamp(MIN_CHUNK_EVENTS, CHUNK_EVENTS)
}

/// Which [`ChunkLanes`] lanes a sink reads — the per-lane needs-mask.
///
/// Derived once per flush from [`Instrument::lane_needs`]:
/// [`EventChunk::flush_into`] builds only the union of the requested lanes,
/// so subset runs never pay for lanes nobody sweeps (tags-only for
/// `--metrics mix`; addrs-only for `reuse`/`mem_entropy`; the sizes lane
/// and store bitset only when the `traffic` family is enabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneMask(u8);

impl LaneMask {
    pub const NONE: LaneMask = LaneMask(0);
    /// The one-byte op-tag lane (`mix`).
    pub const TAGS: LaneMask = LaneMask(1 << 0);
    /// Packed memory-access addresses (`reuse`, `mem_entropy`, `traffic`).
    pub const ADDRS: LaneMask = LaneMask(1 << 1);
    /// Access sizes in bytes (`traffic` byte accounting).
    pub const SIZES: LaneMask = LaneMask(1 << 2);
    /// The store bitset (`traffic` write/writeback accounting).
    pub const STORES: LaneMask = LaneMask(1 << 3);
    pub const ALL: LaneMask = LaneMask(0b1111);

    #[inline]
    pub fn contains(self, other: LaneMask) -> bool {
        self.0 & other.0 == other.0
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }
}

impl std::ops::BitOr for LaneMask {
    type Output = LaneMask;

    #[inline]
    fn bitor(self, rhs: LaneMask) -> LaneMask {
        LaneMask(self.0 | rhs.0)
    }
}

impl std::ops::BitOrAssign for LaneMask {
    #[inline]
    fn bitor_assign(&mut self, rhs: LaneMask) {
        self.0 |= rhs.0;
    }
}

/// Op-tag lane sentinel: a dynamic basic-block entry.
pub const TAG_BLOCK: u8 = 0xFD;
/// Op-tag lane sentinel: a conditional branch that was taken.
pub const TAG_BR_TAKEN: u8 = 0xFE;
/// Op-tag lane sentinel: a conditional branch that fell through.
pub const TAG_BR_NOT: u8 = 0xFF;

// instruction tags are raw `Op::index()` values; the sentinels above must
// stay out of that range
const _: () = assert!(Op::COUNT <= TAG_BLOCK as usize);

/// Structure-of-arrays view of one event chunk, built once per chunk and
/// shared by every lane-capable analyzer (see [`Instrument::on_chunk_lanes`]).
///
/// Lanes:
/// - `tags`: one byte per event — `Op::index()` for instructions, or one of
///   [`TAG_BLOCK`] / [`TAG_BR_TAKEN`] / [`TAG_BR_NOT`] (the `mix` sweep).
/// - `addrs`: the chunk's memory-access addresses, densely packed in trace
///   order (the `reuse` / `mem_entropy` sweeps).
/// - `sizes`: access sizes in bytes, parallel to `addrs`.
/// - store bitset: bit *i* set ⇔ `addrs[i]` is a store.
///
/// Allocations are retained across rebuilds, so a recycled [`EventChunk`]
/// (or an [`crate::analysis::AnalyzerStack`] fallback scratch) pays the
/// build cost only in cache-friendly linear writes.
#[derive(Debug, Clone, Default)]
pub struct ChunkLanes {
    tags: Vec<u8>,
    addrs: Vec<u64>,
    sizes: Vec<u8>,
    store_bits: Vec<u64>,
    /// Memory accesses in the chunk — counted even when no memory lane is
    /// built, so [`Self::n_mem`] stays meaningful under any needs-mask.
    n_mem: usize,
    /// Which lanes the last rebuild actually built. Reads of unbuilt lanes
    /// are caught by debug asserts in debug builds; in release builds the
    /// accessors return the unbuilt lane's empty contents, so sinks must
    /// only read lanes covered by their own `lane_needs()` mask.
    built: LaneMask,
}

impl ChunkLanes {
    /// Rebuild every lane from `events` (previous contents discarded,
    /// allocations reused).
    pub fn rebuild(&mut self, events: &[TraceEvent]) {
        self.rebuild_masked(events, LaneMask::ALL);
    }

    /// Rebuild only the lanes in `needs` (the per-family needs-mask —
    /// see [`Instrument::lane_needs`]); unrequested lanes are cleared so a
    /// recycled chunk can never leak a stale lane to the wrong sink.
    pub fn rebuild_masked(&mut self, events: &[TraceEvent], needs: LaneMask) {
        self.tags.clear();
        self.addrs.clear();
        self.sizes.clear();
        self.store_bits.clear();
        self.n_mem = 0;
        self.built = needs;
        let want_tags = needs.contains(LaneMask::TAGS);
        let want_addrs = needs.contains(LaneMask::ADDRS);
        let want_sizes = needs.contains(LaneMask::SIZES);
        let want_stores = needs.contains(LaneMask::STORES);
        if want_tags {
            self.tags.reserve(events.len());
        }
        for ev in events {
            match ev {
                TraceEvent::BlockEnter { .. } => {
                    if want_tags {
                        self.tags.push(TAG_BLOCK);
                    }
                }
                TraceEvent::Branch { taken, .. } => {
                    if want_tags {
                        self.tags.push(if *taken { TAG_BR_TAKEN } else { TAG_BR_NOT });
                    }
                }
                TraceEvent::Instr(i) => {
                    if want_tags {
                        self.tags.push(i.op.index() as u8);
                    }
                    if let Some(m) = i.mem {
                        let slot = self.n_mem;
                        self.n_mem += 1;
                        if want_stores {
                            if slot % 64 == 0 {
                                self.store_bits.push(0);
                            }
                            if m.is_store {
                                self.store_bits[slot / 64] |= 1 << (slot % 64);
                            }
                        }
                        if want_addrs {
                            self.addrs.push(m.addr);
                        }
                        if want_sizes {
                            self.sizes.push(m.size);
                        }
                    }
                }
            }
        }
    }

    /// One tag byte per event, parallel to the event slice.
    #[inline]
    pub fn tags(&self) -> &[u8] {
        &self.tags
    }

    /// Packed memory-access addresses, trace order.
    #[inline]
    pub fn addrs(&self) -> &[u64] {
        &self.addrs
    }

    /// Access sizes in bytes, parallel to [`Self::addrs`].
    #[inline]
    pub fn sizes(&self) -> &[u8] {
        &self.sizes
    }

    /// Number of events the lanes describe (length of the tags lane — only
    /// meaningful when [`LaneMask::TAGS`] was requested).
    #[inline]
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    /// Number of memory accesses in the chunk (tracked under any
    /// needs-mask, even when no memory lane was built).
    #[inline]
    pub fn n_mem(&self) -> usize {
        self.n_mem
    }

    /// Is the `i`-th memory access (index into the packed access order) a
    /// store? Requires the [`LaneMask::STORES`] lane.
    #[inline]
    pub fn is_store(&self, i: usize) -> bool {
        debug_assert!(self.built.contains(LaneMask::STORES), "STORES lane not built");
        debug_assert!(i < self.n_mem);
        (self.store_bits[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Total stores in the chunk (popcount of the store bitset; requires
    /// the [`LaneMask::STORES`] lane).
    pub fn stores(&self) -> u64 {
        debug_assert!(self.built.contains(LaneMask::STORES), "STORES lane not built");
        self.store_bits.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Total loads in the chunk (requires the [`LaneMask::STORES`] lane).
    pub fn loads(&self) -> u64 {
        self.n_mem as u64 - self.stores()
    }
}

/// Reusable fixed-capacity event buffer. The interpreter owns a small
/// number of these (one on the inline path, a recycled pool on the offload
/// path) and reuses their allocations for the whole run; `flush_into` hands
/// the buffered slice — plus its [`ChunkLanes`] view when the sink wants
/// one — to a sink and clears it.
#[derive(Debug, Clone)]
pub struct EventChunk {
    buf: Vec<TraceEvent>,
    capacity: usize,
    lanes: ChunkLanes,
}

impl Default for EventChunk {
    fn default() -> Self {
        Self::new()
    }
}

impl EventChunk {
    pub fn new() -> Self {
        Self::with_capacity(CHUNK_EVENTS)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        EventChunk {
            buf: Vec::with_capacity(capacity),
            capacity,
            lanes: ChunkLanes::default(),
        }
    }

    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        debug_assert!(self.buf.len() < self.capacity);
        self.buf.push(ev);
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.buf.len() >= self.capacity
    }

    /// Fixed capacity this chunk was created with (the flush threshold —
    /// the backing allocation never grows past it on the hot path).
    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Free slots before the buffer must be flushed.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.capacity - self.buf.len()
    }

    /// The one block-boundary flush policy both the inline (`Machine::run`)
    /// and offload delivery sinks consult, so their chunk boundaries can
    /// never drift apart: flush when the buffer lacks headroom for a block
    /// of `upcoming` instructions plus its BlockEnter and a possible
    /// terminating Branch event.
    #[inline]
    pub(crate) fn needs_flush_for_block(&self, upcoming: usize) -> bool {
        self.remaining() < upcoming + 2
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.buf
    }

    /// Drop buffered events without delivering them (offload teardown when
    /// the analysis thread is already gone, sharded-pool recycling).
    pub(crate) fn clear(&mut self) {
        self.buf.clear();
    }

    /// Build this chunk's [`ChunkLanes`] view in place, restricted to the
    /// lanes in `needs`, without delivering or clearing the events. The
    /// sharded pipeline calls this once per chunk — on the broadcaster
    /// thread, with the **union** of every shard's
    /// [`Instrument::lane_needs`] mask — before sharing the chunk
    /// immutably with all analyzer workers; [`Self::lanes`] then serves
    /// every worker's sweep.
    pub fn build_lanes(&mut self, needs: LaneMask) {
        self.lanes.rebuild_masked(&self.buf, needs);
    }

    /// The lanes view last built by [`Self::build_lanes`] (or by
    /// [`Self::flush_into`] for its sink). Readers must only touch lanes
    /// covered by the mask that built them.
    #[inline]
    pub fn lanes(&self) -> &ChunkLanes {
        &self.lanes
    }

    /// Hand the buffered events to `sink` in one chunk call and reset the
    /// buffer (allocations retained). When the sink consumes lanes
    /// ([`Instrument::wants_lanes`]), the [`ChunkLanes`] view is built here,
    /// once — restricted to the lanes the sink's [`Instrument::lane_needs`]
    /// mask actually reads — and shared by every lane-capable analyzer
    /// behind the sink.
    #[inline]
    pub fn flush_into(&mut self, sink: &mut dyn Instrument) {
        if self.buf.is_empty() {
            return;
        }
        let needs = sink.lane_needs();
        if !needs.is_empty() {
            self.lanes.rebuild_masked(&self.buf, needs);
            sink.on_chunk_lanes(&self.buf, &self.lanes);
        } else {
            sink.on_chunk(&self.buf);
        }
        self.buf.clear();
    }
}

/// Analyzer interface.
///
/// The chunked paths are the hot paths: the interpreter delivers events in
/// chunks (see [`EventChunk`]), so a `dyn Instrument` costs one virtual
/// call per chunk instead of one per event, and the per-event handling
/// inside an implementation is statically dispatched and inlinable.
/// `on_event` is the per-event reference semantics; implementations must
/// not allocate per call on common paths, and overridden chunk methods must
/// fold the slice in order, exactly as the defaults do.
pub trait Instrument {
    fn on_event(&mut self, ev: &TraceEvent);

    /// Consume a batch of events in trace order. Override to hoist
    /// per-chunk state; must be observationally identical to calling
    /// `on_event` on each element in order.
    #[inline]
    fn on_chunk(&mut self, events: &[TraceEvent]) {
        for ev in events {
            self.on_event(ev);
        }
    }

    /// Lane-aware hot path: the chunk's events plus the SoA [`ChunkLanes`]
    /// view, built once per chunk by [`EventChunk::flush_into`].
    /// Lane-capable analyzers override this to sweep the dense lanes
    /// instead of matching the enum; the default ignores the lanes. Must be
    /// observationally identical to `on_chunk(events)`.
    #[inline]
    fn on_chunk_lanes(&mut self, events: &[TraceEvent], _lanes: &ChunkLanes) {
        self.on_chunk(events);
    }

    /// True when this sink consumes [`ChunkLanes`]. [`EventChunk::flush_into`]
    /// builds the lanes — once per chunk — only if so, keeping the build off
    /// runs that select no lane-capable analyzer.
    #[inline]
    fn wants_lanes(&self) -> bool {
        false
    }

    /// Which lanes this sink actually reads — the per-lane needs-mask.
    /// [`EventChunk::flush_into`] builds only the requested lanes, so
    /// subset runs skip unread lanes entirely (tags-only for
    /// `--metrics mix`, addrs-only for `reuse`/`mem_entropy`, sizes +
    /// store bitset only with `traffic`). The default derives from
    /// [`Self::wants_lanes`]: every lane for a lane-capable sink, none
    /// otherwise; implementations overriding this must keep
    /// `wants_lanes() == !lane_needs().is_empty()`.
    #[inline]
    fn lane_needs(&self) -> LaneMask {
        if self.wants_lanes() {
            LaneMask::ALL
        } else {
            LaneMask::NONE
        }
    }
}

/// No-op sink (pure execution, oracle validation runs).
pub struct NullInstrument;

impl Instrument for NullInstrument {
    #[inline]
    fn on_event(&mut self, _ev: &TraceEvent) {}

    #[inline]
    fn on_chunk(&mut self, _events: &[TraceEvent]) {}
}

/// Fan-out to several analyzers in one pass over the trace.
///
/// Retained for ad-hoc sink composition and as the per-event dispatch
/// baseline in `benches/perf_micro.rs`; the profiling pipeline itself now
/// composes analyzers through `analysis::AnalyzerStack`, which fans chunks
/// out with static dispatch per analyzer.
pub struct Fanout<'a> {
    pub sinks: Vec<&'a mut dyn Instrument>,
}

impl<'a> Fanout<'a> {
    pub fn new(sinks: Vec<&'a mut dyn Instrument>) -> Self {
        Fanout { sinks }
    }
}

impl Instrument for Fanout<'_> {
    #[inline]
    fn on_event(&mut self, ev: &TraceEvent) {
        for s in self.sinks.iter_mut() {
            s.on_event(ev);
        }
    }

    #[inline]
    fn on_chunk(&mut self, events: &[TraceEvent]) {
        for s in self.sinks.iter_mut() {
            s.on_chunk(events);
        }
    }

    #[inline]
    fn on_chunk_lanes(&mut self, events: &[TraceEvent], lanes: &ChunkLanes) {
        for s in self.sinks.iter_mut() {
            s.on_chunk_lanes(events, lanes);
        }
    }

    fn wants_lanes(&self) -> bool {
        self.sinks.iter().any(|s| s.wants_lanes())
    }

    fn lane_needs(&self) -> LaneMask {
        self.sinks
            .iter()
            .fold(LaneMask::NONE, |acc, s| acc | s.lane_needs())
    }
}

/// Event counter (tests, quick stats). Chunk delivery uses the default
/// `on_chunk` loop — nothing worth hoisting here.
#[derive(Default, Debug, Clone)]
pub struct Counter {
    pub instrs: u64,
    pub blocks: u64,
    pub branches: u64,
    pub loads: u64,
    pub stores: u64,
}

impl Instrument for Counter {
    fn on_event(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::BlockEnter { .. } => self.blocks += 1,
            TraceEvent::Branch { .. } => self.branches += 1,
            TraceEvent::Instr(i) => {
                self.instrs += 1;
                if let Some(m) = i.mem {
                    if m.is_store {
                        self.stores += 1;
                    } else {
                        self.loads += 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instr_ev(op: Op) -> TraceEvent {
        TraceEvent::Instr(InstrEvent {
            op,
            dst: Some(0),
            srcs: [0; 3],
            n_srcs: 0,
            mem: None,
            block: 0,
        })
    }

    fn mem_ev(op: Op, addr: u64, size: u8, is_store: bool) -> TraceEvent {
        TraceEvent::Instr(InstrEvent {
            op,
            dst: if is_store { None } else { Some(1) },
            srcs: [0; 3],
            n_srcs: if is_store { 2 } else { 1 },
            mem: Some(MemAccess { addr, size, is_store }),
            block: 0,
        })
    }

    #[test]
    fn counter_counts() {
        let mut c = Counter::default();
        c.on_event(&TraceEvent::BlockEnter { block: 0 });
        c.on_event(&instr_ev(Op::ConstI));
        c.on_event(&mem_ev(Op::Load, 64, 8, false));
        c.on_event(&TraceEvent::Branch { block: 0, taken: true });
        assert_eq!((c.blocks, c.instrs, c.loads, c.branches), (1, 2, 1, 1));
    }

    #[test]
    fn counter_chunk_matches_per_event() {
        let events = vec![
            TraceEvent::BlockEnter { block: 0 },
            instr_ev(Op::ConstI),
            mem_ev(Op::Store, 8, 8, true),
            TraceEvent::Branch { block: 0, taken: false },
        ];
        let mut a = Counter::default();
        let mut b = Counter::default();
        for ev in &events {
            a.on_event(ev);
        }
        b.on_chunk(&events);
        assert_eq!(
            (a.instrs, a.blocks, a.branches, a.loads, a.stores),
            (b.instrs, b.blocks, b.branches, b.loads, b.stores)
        );
    }

    #[test]
    fn lanes_pack_tags_and_mem_accesses() {
        let events = vec![
            TraceEvent::BlockEnter { block: 3 },
            mem_ev(Op::Load, 0x100, 8, false),
            instr_ev(Op::FAdd),
            mem_ev(Op::Store, 0x108, 4, true),
            TraceEvent::Branch { block: 3, taken: true },
            TraceEvent::Branch { block: 3, taken: false },
        ];
        let mut lanes = ChunkLanes::default();
        lanes.rebuild(&events);
        assert_eq!(lanes.len(), 6);
        assert_eq!(
            lanes.tags(),
            &[
                TAG_BLOCK,
                Op::Load.index() as u8,
                Op::FAdd.index() as u8,
                Op::Store.index() as u8,
                TAG_BR_TAKEN,
                TAG_BR_NOT
            ]
        );
        assert_eq!(lanes.addrs(), &[0x100, 0x108]);
        assert_eq!(lanes.sizes(), &[8, 4]);
        assert_eq!(lanes.n_mem(), 2);
        assert!(!lanes.is_store(0));
        assert!(lanes.is_store(1));
        assert_eq!((lanes.loads(), lanes.stores()), (1, 1));
        // rebuild reuses allocations and discards old contents
        lanes.rebuild(&events[..1]);
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes.n_mem(), 0);
        assert_eq!(lanes.stores(), 0);
    }

    #[test]
    fn masked_rebuild_builds_only_requested_lanes() {
        let events = vec![
            TraceEvent::BlockEnter { block: 1 },
            mem_ev(Op::Load, 0x100, 8, false),
            mem_ev(Op::Store, 0x108, 4, true),
            TraceEvent::Branch { block: 1, taken: true },
        ];
        let mut lanes = ChunkLanes::default();

        // tags-only (the `--metrics mix` shape): no memory lanes built,
        // but the access count is still tracked
        lanes.rebuild_masked(&events, LaneMask::TAGS);
        assert_eq!(lanes.len(), 4);
        assert_eq!(lanes.addrs(), &[] as &[u64]);
        assert_eq!(lanes.sizes(), &[] as &[u8]);
        assert_eq!(lanes.n_mem(), 2);

        // addrs-only (the `reuse`/`mem_entropy` shape), from a recycled
        // lanes struct: the stale tags lane must be cleared
        lanes.rebuild_masked(&events, LaneMask::ADDRS);
        assert_eq!(lanes.len(), 0);
        assert_eq!(lanes.addrs(), &[0x100, 0x108]);
        assert_eq!(lanes.sizes(), &[] as &[u8]);
        assert_eq!(lanes.n_mem(), 2);

        // traffic shape: addrs + sizes + store bitset, no tags
        lanes.rebuild_masked(&events, LaneMask::ADDRS | LaneMask::SIZES | LaneMask::STORES);
        assert_eq!(lanes.addrs(), &[0x100, 0x108]);
        assert_eq!(lanes.sizes(), &[8, 4]);
        assert!(!lanes.is_store(0));
        assert!(lanes.is_store(1));
        assert_eq!((lanes.loads(), lanes.stores()), (1, 1));
        assert_eq!(lanes.len(), 0);

        // full rebuild restores everything
        lanes.rebuild(&events);
        assert_eq!(lanes.len(), 4);
        assert_eq!(lanes.n_mem(), 2);
        assert_eq!(lanes.sizes(), &[8, 4]);
    }

    #[test]
    fn lane_mask_algebra() {
        assert!(LaneMask::NONE.is_empty());
        assert!(!LaneMask::TAGS.is_empty());
        assert!(LaneMask::ALL.contains(LaneMask::TAGS | LaneMask::STORES));
        assert!(!LaneMask::TAGS.contains(LaneMask::ADDRS));
        let mut m = LaneMask::NONE;
        m |= LaneMask::SIZES;
        assert!(m.contains(LaneMask::SIZES));
        assert!(!m.contains(LaneMask::ALL));
    }

    #[test]
    fn flush_respects_sink_lane_needs() {
        /// A sink that wants only the addrs lane and asserts nothing else
        /// was built.
        #[derive(Default)]
        struct AddrOnly {
            mem_seen: u64,
        }
        impl Instrument for AddrOnly {
            fn on_event(&mut self, _ev: &TraceEvent) {}
            fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
                assert_eq!(lanes.len(), 0, "tags lane must not be built");
                assert!(lanes.sizes().is_empty(), "sizes lane must not be built");
                self.mem_seen += lanes.addrs().len() as u64;
            }
            fn wants_lanes(&self) -> bool {
                true
            }
            fn lane_needs(&self) -> LaneMask {
                LaneMask::ADDRS
            }
        }
        let mut ch = EventChunk::with_capacity(8);
        ch.push(mem_ev(Op::Load, 0x40, 8, false));
        ch.push(mem_ev(Op::Store, 0x48, 8, true));
        ch.push(instr_ev(Op::Add));
        let mut sink = AddrOnly::default();
        ch.flush_into(&mut sink);
        assert_eq!(sink.mem_seen, 2);
    }

    #[test]
    fn lanes_store_bitset_spans_words() {
        // > 64 accesses: the bitset needs a second word
        let events: Vec<TraceEvent> = (0..130u64)
            .map(|i| mem_ev(Op::Store, i * 8, 8, i % 3 == 0))
            .collect();
        let mut lanes = ChunkLanes::default();
        lanes.rebuild(&events);
        assert_eq!(lanes.n_mem(), 130);
        for i in 0..130 {
            assert_eq!(lanes.is_store(i), i % 3 == 0, "access {i}");
        }
        assert_eq!(lanes.stores(), (0..130).filter(|i| i % 3 == 0).count() as u64);
    }

    /// A sink that consumes lanes: records what it was handed so the flush
    /// contract (lanes built exactly when wanted) is observable.
    #[derive(Default)]
    struct LaneProbe {
        chunk_calls: u64,
        lane_calls: u64,
        mem_seen: u64,
    }

    impl Instrument for LaneProbe {
        fn on_event(&mut self, _ev: &TraceEvent) {}

        fn on_chunk(&mut self, _events: &[TraceEvent]) {
            self.chunk_calls += 1;
        }

        fn on_chunk_lanes(&mut self, events: &[TraceEvent], lanes: &ChunkLanes) {
            assert_eq!(events.len(), lanes.len());
            self.lane_calls += 1;
            self.mem_seen += lanes.n_mem() as u64;
        }

        fn wants_lanes(&self) -> bool {
            true
        }
    }

    #[test]
    fn flush_builds_lanes_only_for_lane_sinks() {
        let mut ch = EventChunk::with_capacity(8);
        ch.push(mem_ev(Op::Load, 0x40, 8, false));
        ch.push(instr_ev(Op::Add));
        let mut probe = LaneProbe::default();
        ch.flush_into(&mut probe);
        assert_eq!((probe.lane_calls, probe.chunk_calls, probe.mem_seen), (1, 0, 1));
        assert!(ch.is_empty());

        // a non-lane sink goes through plain on_chunk
        ch.push(instr_ev(Op::Add));
        let mut c = Counter::default();
        ch.flush_into(&mut c);
        assert_eq!(c.instrs, 1);
    }

    #[test]
    fn fanout_reaches_all_and_propagates_lane_wish() {
        let mut a = Counter::default();
        let mut b = Counter::default();
        {
            let mut f = Fanout::new(vec![&mut a, &mut b]);
            f.on_event(&instr_ev(Op::Add));
            assert!(!f.wants_lanes());
        }
        assert_eq!(a.instrs, 1);
        assert_eq!(b.instrs, 1);

        let mut probe = LaneProbe::default();
        let mut c = Counter::default();
        let mut f = Fanout::new(vec![&mut c, &mut probe]);
        assert!(f.wants_lanes());
        let evs = [mem_ev(Op::Load, 0x10, 8, false)];
        let mut lanes = ChunkLanes::default();
        lanes.rebuild(&evs);
        f.on_chunk_lanes(&evs, &lanes);
        drop(f);
        assert_eq!(probe.lane_calls, 1);
        assert_eq!(c.loads, 1);
    }

    #[test]
    fn chunk_flushes_and_recycles() {
        let mut ch = EventChunk::with_capacity(4);
        assert!(ch.is_empty());
        for _ in 0..4 {
            ch.push(instr_ev(Op::Add));
        }
        assert!(ch.is_full());
        assert_eq!(ch.remaining(), 0);
        let mut c = Counter::default();
        ch.flush_into(&mut c);
        assert!(ch.is_empty());
        assert_eq!(c.instrs, 4);
        // flushing an empty chunk is a no-op (no zero-length on_chunk call)
        ch.flush_into(&mut c);
        assert_eq!(c.instrs, 4);
    }

    #[test]
    fn adaptive_capacity_pins_heuristic() {
        use crate::ir::ProgramBuilder;

        // streaming: one giant straight-line block ⇒ full buffer
        let mut b = ProgramBuilder::new("streaming");
        let mut x = b.const_f(1.0);
        for _ in 0..200 {
            x = b.fadd(x, x);
        }
        let p = b.finish(Some(x));
        assert_eq!(adaptive_chunk_capacity(&p), CHUNK_EVENTS);

        // branchy: many tiny blocks ⇒ clamped to the floor
        let mut b = ProgramBuilder::new("branchy");
        let one = b.const_i(1);
        let two = b.const_i(2);
        let c = b.cmp_lt(one, two);
        for _ in 0..12 {
            b.if_then_else(
                c,
                |b| {
                    b.const_i(1);
                },
                |b| {
                    b.const_i(2);
                },
            );
        }
        let p = b.finish(None);
        let blocks = p.func.blocks.len();
        let mean_events = p.func.static_instrs() / blocks + 2;
        assert!(mean_events < 8, "branchy program should have short blocks");
        assert_eq!(adaptive_chunk_capacity(&p), MIN_CHUNK_EVENTS);

        // mid-density: ~30 instrs/block lands between floor and ceiling
        let mut b = ProgramBuilder::new("mid");
        let n = b.const_i(4);
        b.counted_loop(n, |b, _i| {
            let mut x = b.const_f(1.0);
            for _ in 0..28 {
                x = b.fadd(x, x);
            }
            b.fabs(x);
        });
        let p = b.finish(None);
        let cap = adaptive_chunk_capacity(&p);
        assert!(cap.is_power_of_two());
        assert!((MIN_CHUNK_EVENTS..=CHUNK_EVENTS).contains(&cap));
    }
}
