//! Sharded analysis: broadcast each event chunk to N analyzer workers.
//!
//! [`run_sharded`] generalizes the offload topology from one consumer to a
//! pool: the interpreter ships owned [`EventChunk`]s to a **broadcaster**
//! thread, which builds the chunk's SoA lanes once — restricted to the
//! union of every shard's [`Instrument::lane_needs`] mask — wraps the
//! chunk in an `Arc`, and clones it to one bounded channel per worker.
//! Each worker owns one shard (an `Instrument` that folds a disjoint
//! subset of the analyzers — see `analysis::ShardPlan` for the
//! family-level policy) and sweeps the shared events/lanes read-only, so
//! no analyzer state ever crosses a thread boundary.
//!
//! ## Countdown-return recycling
//!
//! The broadcaster's **final send moves its own handle**, so once a chunk
//! is distributed exactly `N` `Arc` references exist — one per worker,
//! never a stray broadcaster reference that could race the countdown.
//! Each worker, done folding, sends its reference back to the producer
//! over a shared return channel. The producer drains that channel when it
//! needs a fresh buffer: the first `N-1` references of a chunk fail
//! `Arc::try_unwrap` and are dropped here; the `N`-th — the countdown
//! hitting zero — unwraps back into an owned buffer, which is cleared and
//! refilled. No atomic counters beyond the `Arc`'s own, no locks, no
//! spinning.
//!
//! The pool is fixed at [`SHARDED_POOL_CHUNKS`] buffers, so when every
//! buffer is in flight the producer blocks on the return channel —
//! exactly the offload path's backpressure, now gated on the *slowest*
//! worker (its bounded input queue stalls the broadcaster, which stalls
//! the producer's channel). Event order per worker is the emission order:
//! one FIFO hop producer→broadcaster and one broadcaster→worker, so every
//! shard folds the same sequence the inline path would hand it —
//! bit-identical metrics (gated by `rust/tests/prop_chunked.rs`).

use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::super::events::{EventChunk, Instrument, LaneMask};
use super::super::machine::{Machine, Outcome};
use super::{BufferSource, CourierSink, OFFLOAD_QUEUE_CHUNKS};

/// Bound of each worker's input channel: how many chunks may queue ahead
/// of one shard before the broadcaster blocks on it.
pub const SHARDED_QUEUE_CHUNKS: usize = 2;

/// Owned chunks cycling through the sharded pipeline: one being filled,
/// up to [`OFFLOAD_QUEUE_CHUNKS`] queued to the broadcaster, one being
/// laned, up to [`SHARDED_QUEUE_CHUNKS`] + 1 fanned out to the workers.
/// Independent of the worker count — workers share references, not
/// copies, so N does not multiply resident trace memory.
pub const SHARDED_POOL_CHUNKS: usize = OFFLOAD_QUEUE_CHUNKS + SHARDED_QUEUE_CHUNKS + 3;

/// Sharded topology's [`BufferSource`]: primed spares first, then the
/// countdown-return channel — blocking when the whole pool is in flight.
struct CountdownPool {
    returned: Receiver<Arc<EventChunk>>,
    /// Buffers not yet inducted into circulation (pool priming).
    spares: Vec<EventChunk>,
}

impl BufferSource for CountdownPool {
    fn next_buffer(&mut self) -> Option<EventChunk> {
        if let Some(c) = self.spares.pop() {
            return Some(c);
        }
        loop {
            match self.returned.recv() {
                Ok(arc) => {
                    if let Ok(mut chunk) = Arc::try_unwrap(arc) {
                        // last reference: every worker has folded it
                        chunk.clear();
                        return Some(chunk);
                    }
                    // countdown not at zero yet — another worker still
                    // holds this chunk; our reference is dropped, keep
                    // draining
                }
                Err(_) => return None,
            }
        }
    }
}

/// Execute `machine` to completion with each chunk broadcast to one
/// worker thread per shard. Every shard folds the complete event stream
/// in emission order; shards are moved to their worker threads for the
/// duration of the run (hence `Send`) and handed back — through the
/// borrows — when this returns. With a single shard this degenerates to
/// the offload topology plus one hop; metrics are bit-identical to
/// [`Machine::run`] in every configuration.
pub fn run_sharded(
    machine: &mut Machine<'_>,
    shards: &mut [&mut (dyn Instrument + Send)],
) -> Result<Outcome> {
    if shards.is_empty() {
        bail!("sharded pipeline needs at least one analyzer shard");
    }
    let capacity = machine.chunk_capacity();
    // the broadcaster builds exactly the lanes some shard will read
    let union_needs = shards.iter().fold(LaneMask::NONE, |acc, s| acc | s.lane_needs());
    let n_workers = shards.len();

    let t0 = Instant::now();
    let mut outcome = std::thread::scope(|s| -> Result<Outcome> {
        let (full_tx, full_rx) = mpsc::sync_channel::<EventChunk>(OFFLOAD_QUEUE_CHUNKS);
        let (return_tx, return_rx) = mpsc::channel::<Arc<EventChunk>>();

        let mut worker_txs: Vec<SyncSender<Arc<EventChunk>>> = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for shard in shards.iter_mut() {
            let (tx, rx) = mpsc::sync_channel::<Arc<EventChunk>>(SHARDED_QUEUE_CHUNKS);
            worker_txs.push(tx);
            let return_tx = return_tx.clone();
            workers.push(s.spawn(move || {
                // the worker owns its shard until the broadcast channel
                // closes; lanes were pre-built, so `on_chunk_lanes` is the
                // one delivery every shard takes (a lane-less shard's
                // default forwards to `on_chunk`)
                while let Ok(chunk) = rx.recv() {
                    shard.on_chunk_lanes(chunk.events(), chunk.lanes());
                    // countdown-return: hand our reference to the producer;
                    // it may already be gone on error teardown
                    let _ = return_tx.send(chunk);
                }
            }));
        }
        // the producer must see the channel close when the workers exit
        drop(return_tx);

        let broadcaster = s.spawn(move || {
            let (last_tx, rest_txs) = worker_txs.split_last().expect("at least one worker");
            while let Ok(mut chunk) = full_rx.recv() {
                // no lane-capable shard → skip the per-event lane sweep
                // entirely, exactly as the inline/offload flush would
                if !union_needs.is_empty() {
                    chunk.build_lanes(union_needs);
                }
                let shared = Arc::new(chunk);
                for tx in rest_txs {
                    if tx.send(Arc::clone(&shared)).is_err() {
                        // a worker died (panic teardown): stop broadcasting
                        // so the producer detaches and the join surfaces it
                        return;
                    }
                }
                // the final send MOVES our handle: after distribution
                // exactly one reference per worker exists, so the
                // producer's countdown can never race a stray broadcaster
                // reference into deallocating (instead of recycling) the
                // buffer
                if last_tx.send(shared).is_err() {
                    return;
                }
            }
        });

        let pool = CountdownPool {
            returned: return_rx,
            spares: (0..SHARDED_POOL_CHUNKS - 1)
                .map(|_| EventChunk::with_capacity(capacity))
                .collect(),
        };
        let mut delivery = CourierSink::new(full_tx, pool, capacity);
        let run = machine.run_with(&mut delivery);
        // closing the chunk channel lets the broadcaster and workers drain
        // what's in flight and exit; join before returning so every event
        // is folded
        drop(delivery);
        if let Err(payload) = broadcaster.join() {
            std::panic::resume_unwind(payload);
        }
        for w in workers {
            if let Err(payload) = w.join() {
                // a shard panic must surface with its original message,
                // exactly as it would on the inline path
                std::panic::resume_unwind(payload);
            }
        }
        run
    })?;
    // report the overlap-inclusive wall time (interpretation + broadcast +
    // the slowest worker's drain) so events_per_sec stays honest across
    // pipeline modes
    outcome.stats.wall_s = t0.elapsed().as_secs_f64();
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::events::{ChunkLanes, Counter, TraceEvent};
    use crate::ir::{Program, ProgramBuilder};

    fn loop_program(n: i64) -> Program {
        let mut b = ProgramBuilder::new("sh");
        let a = b.alloc_f64("a", 64);
        let len = b.const_i(64);
        let trip = b.const_i(n);
        b.counted_loop(trip, |b, i| {
            let idx = b.rem(i, len);
            let v = b.load_f64(a, idx);
            let w = b.fadd(v, v);
            b.store_f64(a, idx, w);
        });
        b.finish(None)
    }

    fn run_counters(p: &Program, n_shards: usize) -> (Outcome, Vec<Counter>) {
        let mut counters = vec![Counter::default(); n_shards];
        let out = {
            let mut refs: Vec<&mut (dyn Instrument + Send)> = counters
                .iter_mut()
                .map(|c| c as &mut (dyn Instrument + Send))
                .collect();
            run_sharded(&mut Machine::new(p).unwrap(), &mut refs).unwrap()
        };
        (out, counters)
    }

    #[test]
    fn every_shard_sees_the_full_stream() {
        let p = loop_program(5000);
        let mut inline = Counter::default();
        let o1 = Machine::new(&p).unwrap().run(&mut inline).unwrap();
        for n_shards in [1, 2, 3, 5] {
            let (o2, counters) = run_counters(&p, n_shards);
            assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs, "{n_shards} shards");
            assert_eq!(o1.stats.dyn_blocks, o2.stats.dyn_blocks);
            for (i, c) in counters.iter().enumerate() {
                assert_eq!(
                    (c.instrs, c.blocks, c.branches, c.loads, c.stores),
                    (inline.instrs, inline.blocks, inline.branches, inline.loads, inline.stores),
                    "shard {i} of {n_shards}"
                );
            }
            assert!(o2.stats.wall_s > 0.0);
            assert!(o2.stats.events_per_sec() > 0.0);
        }
    }

    #[test]
    fn zero_shards_is_an_error() {
        let p = loop_program(4);
        let mut refs: Vec<&mut (dyn Instrument + Send)> = Vec::new();
        assert!(run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).is_err());
    }

    #[test]
    fn interpreter_error_propagates_through_sharded() {
        let mut b = ProgramBuilder::new("dz");
        let x = b.const_i(1);
        let z = b.const_i(0);
        b.div(x, z);
        let p = b.finish(None);
        let mut c1 = Counter::default();
        let mut c2 = Counter::default();
        let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut c1, &mut c2];
        assert!(run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).is_err());
    }

    #[test]
    fn lane_union_covers_every_shard() {
        // one tags-only shard + one addrs-only shard: the broadcast must
        // build both lanes, and each shard must see its own lane populated
        struct TagsOnly {
            events_seen: u64,
        }
        impl Instrument for TagsOnly {
            fn on_event(&mut self, _ev: &TraceEvent) {}
            fn on_chunk_lanes(&mut self, events: &[TraceEvent], lanes: &ChunkLanes) {
                assert_eq!(lanes.len(), events.len(), "tags lane must be built");
                self.events_seen += lanes.len() as u64;
            }
            fn wants_lanes(&self) -> bool {
                true
            }
            fn lane_needs(&self) -> LaneMask {
                LaneMask::TAGS
            }
        }
        struct AddrsOnly {
            mem_seen: u64,
        }
        impl Instrument for AddrsOnly {
            fn on_event(&mut self, _ev: &TraceEvent) {}
            fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
                self.mem_seen += lanes.addrs().len() as u64;
            }
            fn wants_lanes(&self) -> bool {
                true
            }
            fn lane_needs(&self) -> LaneMask {
                LaneMask::ADDRS
            }
        }
        let p = loop_program(2000);
        let mut inline = Counter::default();
        Machine::new(&p).unwrap().run(&mut inline).unwrap();
        let mut tags = TagsOnly { events_seen: 0 };
        let mut addrs = AddrsOnly { mem_seen: 0 };
        {
            let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut tags, &mut addrs];
            run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).unwrap();
        }
        assert_eq!(tags.events_seen, inline.instrs + inline.blocks + inline.branches);
        assert_eq!(addrs.mem_seen, inline.loads + inline.stores);
    }
}
