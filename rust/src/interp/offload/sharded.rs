//! Sharded analysis: broadcast each event chunk to N analyzer workers.
//!
//! [`run_sharded`] generalizes the offload topology from one consumer to a
//! pool: the interpreter ships owned [`EventChunk`]s to a **broadcaster**
//! thread, which builds the chunk's SoA lanes once — restricted to the
//! union of every shard's [`Instrument::lane_needs`] mask — wraps the
//! chunk in an `Arc`, and clones it to one bounded channel per worker.
//! Each worker owns one shard (an `Instrument` that folds a disjoint
//! subset of the analyzers — see `analysis::ShardPlan` for the
//! family-level policy) and sweeps the shared events/lanes read-only, so
//! no analyzer state ever crosses a thread boundary.
//!
//! ## Countdown-return recycling
//!
//! The broadcaster's **final send moves its own handle**, so once a chunk
//! is distributed exactly `N` `Arc` references exist — one per worker,
//! never a stray broadcaster reference that could race the countdown.
//! Each worker, done folding, sends its reference back to the producer
//! over a shared return channel. The producer drains that channel when it
//! needs a fresh buffer: the first `N-1` references of a chunk fail
//! `Arc::try_unwrap` and are dropped here; the `N`-th — the countdown
//! hitting zero — unwraps back into an owned buffer, which is cleared and
//! refilled. No atomic counters beyond the `Arc`'s own, no locks, no
//! spinning.
//!
//! The pool is fixed at [`SHARDED_POOL_CHUNKS`] buffers, so when every
//! buffer is in flight the producer blocks on the return channel —
//! exactly the offload path's backpressure, now gated on the *slowest*
//! worker (its bounded input queue stalls the broadcaster, which stalls
//! the producer's channel). Event order per worker is the emission order:
//! one FIFO hop producer→broadcaster and one broadcaster→worker, so every
//! shard folds the same sequence the inline path would hand it —
//! bit-identical metrics (gated by `rust/tests/prop_chunked.rs`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::super::events::{EventChunk, Instrument, LaneMask};
use super::super::machine::{Machine, Outcome};
use super::{BufferSource, CourierSink, PipelineRun, OFFLOAD_QUEUE_CHUNKS};
use crate::fault::{panic_message, Deadline, PanicError, Role, ShardFailure, SuperviseOpts};

/// Bound of each worker's input channel: how many chunks may queue ahead
/// of one shard before the broadcaster blocks on it.
pub const SHARDED_QUEUE_CHUNKS: usize = 2;

/// Owned chunks cycling through the sharded pipeline: one being filled,
/// up to [`OFFLOAD_QUEUE_CHUNKS`] queued to the broadcaster, one being
/// laned, up to [`SHARDED_QUEUE_CHUNKS`] + 1 fanned out to the workers.
/// Independent of the worker count — workers share references, not
/// copies, so N does not multiply resident trace memory.
pub const SHARDED_POOL_CHUNKS: usize = OFFLOAD_QUEUE_CHUNKS + SHARDED_QUEUE_CHUNKS + 3;

/// Sharded topology's [`BufferSource`]: primed spares first, then the
/// countdown-return channel — blocking when the whole pool is in flight.
struct CountdownPool {
    returned: Receiver<Arc<EventChunk>>,
    /// Buffers not yet inducted into circulation (pool priming).
    spares: Vec<EventChunk>,
    /// Armed watchdog deadline: bounds the wait so stalled workers
    /// cannot block the producer past `--app-timeout`.
    deadline: Deadline,
}

impl BufferSource for CountdownPool {
    fn next_buffer(&mut self) -> Option<EventChunk> {
        if let Some(c) = self.spares.pop() {
            return Some(c);
        }
        loop {
            let arc = match self.deadline.remaining() {
                None => match self.returned.recv() {
                    Ok(arc) => arc,
                    // a disconnect while the producer still wants buffers
                    // is never a clean shutdown (teardown starts when the
                    // producer drops the courier, after its last call
                    // here) — every worker died mid-run. Detach; the
                    // runner's joins surface each death as a
                    // `ShardFailure` rather than swallowing it.
                    Err(_) => return None,
                },
                Some(left) => match self.returned.recv_timeout(left) {
                    Ok(arc) => arc,
                    // watchdog expiry: detach now; the courier reports
                    // the `TimeoutError` at its next deadline check
                    Err(RecvTimeoutError::Timeout) => return None,
                    Err(RecvTimeoutError::Disconnected) => return None,
                },
            };
            if let Ok(mut chunk) = Arc::try_unwrap(arc) {
                // last reference: every (surviving) worker has folded it
                chunk.clear();
                return Some(chunk);
            }
            // countdown not at zero yet — another worker still holds
            // this chunk; our reference is dropped, keep draining
        }
    }
}

/// Execute `machine` to completion with each chunk broadcast to one
/// worker thread per shard. Every shard folds the complete event stream
/// in emission order; shards are moved to their worker threads for the
/// duration of the run (hence `Send`) and handed back — through the
/// borrows — when this returns. With a single shard this degenerates to
/// the offload topology plus one hop; metrics are bit-identical to
/// [`Machine::run`] in every configuration. Unsupervised wrapper: no
/// faults, no watchdog, and any shard failure becomes an `Err`
/// ([`run_sharded_supervised`] reports them structurally instead).
pub fn run_sharded(
    machine: &mut Machine<'_>,
    shards: &mut [&mut (dyn Instrument + Send)],
) -> Result<Outcome> {
    let run = run_sharded_supervised(machine, shards, SuperviseOpts::default())?;
    if let Some(f) = run.failures.into_iter().next() {
        bail!("analysis shard failed: {f}");
    }
    Ok(run.outcome)
}

/// [`run_sharded`] under supervision: every worker and the broadcaster
/// run under `catch_unwind`, a dead shard degrades to a [`ShardFailure`]
/// while the broadcaster prunes its channel and keeps feeding survivors
/// (whose metrics stay bit-identical to a clean run of just their
/// shards), and the producer arms the `interp` fault site plus the
/// watchdog. `worker:<k>` fault sites collapse onto worker
/// `k % n_workers`.
pub fn run_sharded_supervised(
    machine: &mut Machine<'_>,
    shards: &mut [&mut (dyn Instrument + Send)],
    sup: SuperviseOpts,
) -> Result<PipelineRun> {
    if shards.is_empty() {
        bail!("sharded pipeline needs at least one analyzer shard");
    }
    let capacity = machine.chunk_capacity();
    // the broadcaster builds exactly the lanes some shard will read
    let union_needs = shards.iter().fold(LaneMask::NONE, |acc, s| acc | s.lane_needs());
    let n_workers = shards.len();
    let deadline = sup.deadline();
    let fault = sup.fault;

    let t0 = Instant::now();
    let (mut outcome, failures) =
        std::thread::scope(|s| -> Result<(Outcome, Vec<ShardFailure>)> {
            let (full_tx, full_rx) = mpsc::sync_channel::<EventChunk>(OFFLOAD_QUEUE_CHUNKS);
            let (return_tx, return_rx) = mpsc::channel::<Arc<EventChunk>>();

            let mut worker_txs: Vec<SyncSender<Arc<EventChunk>>> = Vec::with_capacity(n_workers);
            let mut workers = Vec::with_capacity(n_workers);
            for (index, shard) in shards.iter_mut().enumerate() {
                let (tx, rx) = mpsc::sync_channel::<Arc<EventChunk>>(SHARDED_QUEUE_CHUNKS);
                worker_txs.push(tx);
                let return_tx = return_tx.clone();
                workers.push(s.spawn(move || {
                    // the worker owns its shard until the broadcast channel
                    // closes; lanes were pre-built, so `on_chunk_lanes` is
                    // the one delivery every shard takes (a lane-less
                    // shard's default forwards to `on_chunk`). A panic is
                    // caught; the unwind drops `rx` and the held chunk
                    // reference, so the broadcaster prunes this worker and
                    // the countdown still reaches zero for survivors.
                    catch_unwind(AssertUnwindSafe(move || {
                        let mut armed = fault.arm(&[Role::Worker { index, count: n_workers }]);
                        while let Ok(chunk) = rx.recv() {
                            // only panic/stall can target a worker site
                            let _ = armed.tick();
                            shard.on_chunk_lanes(chunk.events(), chunk.lanes());
                            // countdown-return: hand our reference to the
                            // producer; it may already be gone on error
                            // teardown
                            let _ = return_tx.send(chunk);
                        }
                    }))
                    .map_err(panic_message)
                }));
            }
            // the producer must see the channel close when the workers exit
            drop(return_tx);

            let broadcaster = s.spawn(move || {
                catch_unwind(AssertUnwindSafe(move || {
                    let mut armed = fault.arm(&[Role::Broadcaster]);
                    let mut live: Vec<SyncSender<Arc<EventChunk>>> = worker_txs;
                    while let Ok(mut chunk) = full_rx.recv() {
                        let _ = armed.tick();
                        // no lane-capable shard → skip the per-event lane
                        // sweep entirely, exactly as the inline/offload
                        // flush would
                        if !union_needs.is_empty() {
                            chunk.build_lanes(union_needs);
                        }
                        // distribute to the live workers, pruning any that
                        // died. The final live send MOVES our handle: after
                        // distribution exactly one reference per recipient
                        // exists, so the producer's countdown can never
                        // race a stray broadcaster reference into
                        // deallocating (instead of recycling) the buffer.
                        let mut shared = Some(Arc::new(chunk));
                        let mut i = 0;
                        while i < live.len() {
                            let is_last = i + 1 == live.len();
                            let sent = if is_last {
                                live[i].send(shared.take().expect("handle unsent")).is_ok()
                            } else {
                                let arc = shared.as_ref().expect("handle unsent");
                                live[i].send(Arc::clone(arc)).is_ok()
                            };
                            if sent {
                                i += 1;
                            } else {
                                // dead worker (panic teardown): drop its
                                // channel and keep feeding the survivors
                                live.remove(i);
                            }
                        }
                        if live.is_empty() {
                            // every worker is gone — stop broadcasting; the
                            // producer detaches via the pool disconnect and
                            // the joins report each death
                            return;
                        }
                    }
                }))
                .map_err(panic_message)
            });

            let pool = CountdownPool {
                returned: return_rx,
                spares: (0..SHARDED_POOL_CHUNKS - 1)
                    .map(|_| EventChunk::with_capacity(capacity))
                    .collect(),
                deadline,
            };
            let mut delivery = CourierSink::new(full_tx, pool, capacity);
            delivery.supervise(fault.arm(&[Role::Interp]), deadline);
            let run = catch_unwind(AssertUnwindSafe(|| machine.run_with(&mut delivery)));
            // closing the chunk channel lets the broadcaster and workers
            // drain what's in flight and exit; join before returning so
            // every event is folded (or every failure recorded)
            drop(delivery);
            let mut failures: Vec<ShardFailure> = Vec::new();
            for (shard, w) in workers.into_iter().enumerate() {
                match w.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(message)) => {
                        failures.push(ShardFailure { shard, families: Vec::new(), message })
                    }
                    // not reachable: the thread body is fully caught
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
            match broadcaster.join() {
                Ok(Ok(())) => {}
                Ok(Err(message)) => {
                    // a dead broadcaster starves every shard that didn't
                    // already fail on its own
                    for shard in 0..n_workers {
                        if failures.iter().all(|f| f.shard != shard) {
                            failures.push(ShardFailure {
                                shard,
                                families: Vec::new(),
                                message: format!("broadcaster died: {message}"),
                            });
                        }
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
            failures.sort_by_key(|f| f.shard);
            match run {
                Ok(res) => Ok((res?, failures)),
                // an injected producer panic: report it typed, after every
                // analysis thread has been joined (teardown stays clean)
                Err(payload) => Err(PanicError::new("interp", panic_message(payload)).into()),
            }
        })?;
    // report the overlap-inclusive wall time (interpretation + broadcast +
    // the slowest worker's drain) so events_per_sec stays honest across
    // pipeline modes
    outcome.stats.wall_s = t0.elapsed().as_secs_f64();
    Ok(PipelineRun { outcome, failures })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::events::{ChunkLanes, Counter, TraceEvent};
    use crate::ir::{Program, ProgramBuilder};

    fn loop_program(n: i64) -> Program {
        let mut b = ProgramBuilder::new("sh");
        let a = b.alloc_f64("a", 64);
        let len = b.const_i(64);
        let trip = b.const_i(n);
        b.counted_loop(trip, |b, i| {
            let idx = b.rem(i, len);
            let v = b.load_f64(a, idx);
            let w = b.fadd(v, v);
            b.store_f64(a, idx, w);
        });
        b.finish(None)
    }

    fn run_counters(p: &Program, n_shards: usize) -> (Outcome, Vec<Counter>) {
        let mut counters = vec![Counter::default(); n_shards];
        let out = {
            let mut refs: Vec<&mut (dyn Instrument + Send)> = counters
                .iter_mut()
                .map(|c| c as &mut (dyn Instrument + Send))
                .collect();
            run_sharded(&mut Machine::new(p).unwrap(), &mut refs).unwrap()
        };
        (out, counters)
    }

    #[test]
    fn every_shard_sees_the_full_stream() {
        let p = loop_program(5000);
        let mut inline = Counter::default();
        let o1 = Machine::new(&p).unwrap().run(&mut inline).unwrap();
        for n_shards in [1, 2, 3, 5] {
            let (o2, counters) = run_counters(&p, n_shards);
            assert_eq!(o1.stats.dyn_instrs, o2.stats.dyn_instrs, "{n_shards} shards");
            assert_eq!(o1.stats.dyn_blocks, o2.stats.dyn_blocks);
            for (i, c) in counters.iter().enumerate() {
                assert_eq!(
                    (c.instrs, c.blocks, c.branches, c.loads, c.stores),
                    (inline.instrs, inline.blocks, inline.branches, inline.loads, inline.stores),
                    "shard {i} of {n_shards}"
                );
            }
            assert!(o2.stats.wall_s > 0.0);
            assert!(o2.stats.events_per_sec() > 0.0);
        }
    }

    #[test]
    fn zero_shards_is_an_error() {
        let p = loop_program(4);
        let mut refs: Vec<&mut (dyn Instrument + Send)> = Vec::new();
        assert!(run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).is_err());
    }

    #[test]
    fn interpreter_error_propagates_through_sharded() {
        let mut b = ProgramBuilder::new("dz");
        let x = b.const_i(1);
        let z = b.const_i(0);
        b.div(x, z);
        let p = b.finish(None);
        let mut c1 = Counter::default();
        let mut c2 = Counter::default();
        let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut c1, &mut c2];
        assert!(run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).is_err());
    }

    #[test]
    fn dead_shard_degrades_and_survivors_stay_complete() {
        struct Bomb(u64);
        impl Instrument for Bomb {
            fn on_event(&mut self, _ev: &TraceEvent) {
                self.0 += 1;
                if self.0 == 50 {
                    panic!("shard bomb");
                }
            }
        }
        let p = loop_program(5000);
        let mut inline = Counter::default();
        Machine::new(&p).unwrap().run(&mut inline).unwrap();
        let mut bomb = Bomb(0);
        let mut survivor = Counter::default();
        let run = {
            let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut bomb, &mut survivor];
            run_sharded_supervised(
                &mut Machine::new(&p).unwrap(),
                &mut refs,
                SuperviseOpts::default(),
            )
            .unwrap()
        };
        assert_eq!(run.failures.len(), 1);
        assert_eq!(run.failures[0].shard, 0);
        assert!(run.failures[0].message.contains("shard bomb"));
        // the surviving shard saw the complete stream, bit-identical to
        // a clean run
        assert_eq!(
            (survivor.instrs, survivor.blocks, survivor.branches),
            (inline.instrs, inline.blocks, inline.branches)
        );
        // the unsupervised wrapper surfaces the death as an error
        let mut bomb = Bomb(0);
        let mut c = Counter::default();
        let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut bomb, &mut c];
        assert!(run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).is_err());
    }

    #[test]
    fn injected_worker_fault_collapses_onto_modulo_shard() {
        use crate::fault::FaultPlan;
        let p = loop_program(5000);
        let mut c0 = Counter::default();
        let mut c1 = Counter::default();
        // worker:3 with 2 workers → shard 1 takes the panic
        let sup = SuperviseOpts::default()
            .with_fault(FaultPlan::from_spec("panic@worker:3").unwrap());
        let run = {
            let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut c0, &mut c1];
            run_sharded_supervised(&mut Machine::new(&p).unwrap(), &mut refs, sup).unwrap()
        };
        assert_eq!(run.failures.len(), 1);
        assert_eq!(run.failures[0].shard, 1);
        assert!(run.failures[0].message.contains("injected fault"));
    }

    #[test]
    fn lane_union_covers_every_shard() {
        // one tags-only shard + one addrs-only shard: the broadcast must
        // build both lanes, and each shard must see its own lane populated
        struct TagsOnly {
            events_seen: u64,
        }
        impl Instrument for TagsOnly {
            fn on_event(&mut self, _ev: &TraceEvent) {}
            fn on_chunk_lanes(&mut self, events: &[TraceEvent], lanes: &ChunkLanes) {
                assert_eq!(lanes.len(), events.len(), "tags lane must be built");
                self.events_seen += lanes.len() as u64;
            }
            fn wants_lanes(&self) -> bool {
                true
            }
            fn lane_needs(&self) -> LaneMask {
                LaneMask::TAGS
            }
        }
        struct AddrsOnly {
            mem_seen: u64,
        }
        impl Instrument for AddrsOnly {
            fn on_event(&mut self, _ev: &TraceEvent) {}
            fn on_chunk_lanes(&mut self, _events: &[TraceEvent], lanes: &ChunkLanes) {
                self.mem_seen += lanes.addrs().len() as u64;
            }
            fn wants_lanes(&self) -> bool {
                true
            }
            fn lane_needs(&self) -> LaneMask {
                LaneMask::ADDRS
            }
        }
        let p = loop_program(2000);
        let mut inline = Counter::default();
        Machine::new(&p).unwrap().run(&mut inline).unwrap();
        let mut tags = TagsOnly { events_seen: 0 };
        let mut addrs = AddrsOnly { mem_seen: 0 };
        {
            let mut refs: Vec<&mut (dyn Instrument + Send)> = vec![&mut tags, &mut addrs];
            run_sharded(&mut Machine::new(&p).unwrap(), &mut refs).unwrap();
        }
        assert_eq!(tags.events_seen, inline.instrs + inline.blocks + inline.branches);
        assert_eq!(addrs.mem_seen, inline.loads + inline.stores);
    }
}
