//! Flat byte-addressed virtual memory for the execution engine.

use anyhow::{bail, Result};

/// The program memory image. Addresses are virtual (start at the builder's
/// base), stored in one contiguous byte vector for speed; the dynamic trace
/// reports the *virtual* addresses, which is what every memory metric and
/// both machine simulators consume.
pub struct Memory {
    bytes: Vec<u8>,
}

impl Memory {
    /// Build an image of `size` bytes and install the initial data segments.
    pub fn new(size: u64, data: &[(u64, Vec<u8>)]) -> Result<Memory> {
        if size > (1 << 34) {
            bail!("memory image too large: {size} bytes");
        }
        let mut bytes = vec![0u8; size as usize];
        for (base, d) in data {
            let b = *base as usize;
            if b + d.len() > bytes.len() {
                bail!("data segment at 0x{base:x} overflows image");
            }
            bytes[b..b + d.len()].copy_from_slice(d);
        }
        Ok(Memory { bytes })
    }

    #[inline]
    pub fn load(&self, addr: u64, size: u8) -> Result<u64> {
        let a = addr as usize;
        let s = size as usize;
        let Some(slice) = self.bytes.get(a..a + s) else {
            bail!("load out of bounds: 0x{addr:x}+{size}");
        };
        let mut buf = [0u8; 8];
        buf[..s].copy_from_slice(slice);
        Ok(u64::from_le_bytes(buf))
    }

    #[inline]
    pub fn store(&mut self, addr: u64, size: u8, value: u64) -> Result<()> {
        let a = addr as usize;
        let s = size as usize;
        let Some(slice) = self.bytes.get_mut(a..a + s) else {
            bail!("store out of bounds: 0x{addr:x}+{size}");
        };
        slice.copy_from_slice(&value.to_le_bytes()[..s]);
        Ok(())
    }

    pub fn load_f64(&self, addr: u64) -> Result<f64> {
        Ok(f64::from_bits(self.load(addr, 8)?))
    }

    pub fn store_f64(&mut self, addr: u64, v: f64) -> Result<()> {
        self.store(addr, 8, v.to_bits())
    }

    /// Read a whole f64 buffer back out (oracle validation in workloads).
    pub fn read_f64_slice(&self, base: u64, len: usize) -> Result<Vec<f64>> {
        (0..len)
            .map(|i| self.load_f64(base + 8 * i as u64))
            .collect()
    }

    pub fn read_i64_slice(&self, base: u64, len: usize) -> Result<Vec<i64>> {
        (0..len)
            .map(|i| Ok(self.load(base + 8 * i as u64, 8)? as i64))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_sizes() {
        let mut m = Memory::new(4096, &[]).unwrap();
        for (size, val) in [(1u8, 0xABu64), (2, 0xBEEF), (4, 0xDEADBEEF), (8, u64::MAX - 7)] {
            m.store(128, size, val).unwrap();
            assert_eq!(m.load(128, size).unwrap(), val);
        }
    }

    #[test]
    fn f64_roundtrip() {
        let mut m = Memory::new(1024, &[]).unwrap();
        m.store_f64(64, -3.25).unwrap();
        assert_eq!(m.load_f64(64).unwrap(), -3.25);
    }

    #[test]
    fn initial_data_installed() {
        let bytes: Vec<u8> = 7.5f64.to_le_bytes().to_vec();
        let m = Memory::new(256, &[(16, bytes)]).unwrap();
        assert_eq!(m.load_f64(16).unwrap(), 7.5);
    }

    #[test]
    fn oob_rejected() {
        let m = Memory::new(64, &[]).unwrap();
        assert!(m.load(60, 8).is_err());
        assert!(m.load(64, 1).is_err());
    }
}
