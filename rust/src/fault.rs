//! Supervision primitives: deterministic fault injection, watchdog
//! deadlines, and the structured failure types the supervised pipeline
//! runners report instead of unwinding the process.
//!
//! ## Fault plan
//!
//! A [`FaultPlan`] is parsed from the CLI `--inject-fault
//! <kind>@<site>[:<chunk>]` flag and threaded end-to-end exactly like
//! `--hierarchy`/`--mrc` (CLI → coordinator → analysis → interp runners).
//! Kinds: `panic`, `stall:<ms>`, `interp-error`; sites: `interp`,
//! `broadcaster`, `worker:<shard>`. The plan is `Copy` and
//! [`FaultPlan::none`] by default, so the un-injected hot path pays one
//! `Option` check per chunk boundary and nothing else.
//!
//! Every (kind × site) combination fires in **every** delivery mode: a
//! delivery that lacks the named thread collapses the site onto the
//! thread that does that site's work. Inline delivery runs everything on
//! the interpreter thread, so all sites fire there; offload runs the
//! broadcaster+worker roles on its single analysis thread; sharded maps
//! `worker:<shard>` onto worker `shard % n_workers`. The mapping is
//! expressed by arming the plan with the [`Role`]s a thread performs
//! ([`FaultPlan::arm`]).
//!
//! ## Watchdog
//!
//! A [`Deadline`] is armed per app from `--app-timeout <secs>` and
//! checked at chunk boundaries; pool waits switch to `recv_timeout` so a
//! wedged analysis side cannot block the producer past the deadline.
//! Expiry surfaces as a typed [`TimeoutError`] through the normal error
//! path — teardown is the same channel-drop sequence as a clean run, so
//! it is deadlock-free and pool-accounting-clean by construction.

use std::any::Any;
use std::fmt;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// What an injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` on the faulted thread (exercises panic isolation).
    Panic,
    /// Sleep this many milliseconds (exercises the watchdog).
    Stall(u64),
    /// Surface a typed [`InjectedFault`] error from the interpreter loop
    /// (exercises the error path; only valid at site `interp`).
    InterpError,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Stall(_) => "stall",
            FaultKind::InterpError => "interp-error",
        }
    }
}

/// Which pipeline thread the fault targets. Deliveries without that
/// thread collapse the site onto the thread doing its work (see the
/// module doc).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// The producer (interpreter) thread.
    Interp,
    /// The lane-building broadcast thread (sharded), or the single
    /// analysis thread (offload), or the interpreter thread (inline).
    Broadcaster,
    /// Analyzer worker `shard` (sharded: `shard % n_workers`; offload:
    /// the analysis thread; inline: the interpreter thread).
    Worker(usize),
}

impl fmt::Display for FaultSite {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultSite::Interp => write!(f, "interp"),
            FaultSite::Broadcaster => write!(f, "broadcaster"),
            FaultSite::Worker(k) => write!(f, "worker:{k}"),
        }
    }
}

/// A fully-specified injected fault: fire `kind` at `site` when that
/// site processes its `chunk`-th chunk (0-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub site: FaultSite,
    pub chunk: u64,
}

/// The role(s) a pipeline thread performs — what a site is matched
/// against when the plan is armed on that thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Producing chunks (the interpreter loop).
    Interp,
    /// Building lanes / distributing chunks.
    Broadcaster,
    /// Folding analyzer state for every shard (offload/inline collapse).
    AnyWorker,
    /// Folding analyzer state for one shard of `count`.
    Worker { index: usize, count: usize },
}

impl FaultSpec {
    fn matches(&self, role: Role) -> bool {
        match (self.site, role) {
            (FaultSite::Interp, Role::Interp) => true,
            (FaultSite::Broadcaster, Role::Broadcaster) => true,
            (FaultSite::Worker(_), Role::AnyWorker) => true,
            (FaultSite::Worker(k), Role::Worker { index, count }) => k % count.max(1) == index,
            _ => false,
        }
    }
}

/// A deterministic fault-injection plan: at most one [`FaultSpec`],
/// `Copy`, zero-cost when absent. Parsed by [`FaultPlan::from_spec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPlan(Option<FaultSpec>);

impl FaultPlan {
    /// The empty plan: nothing ever fires.
    pub const fn none() -> Self {
        FaultPlan(None)
    }

    pub fn is_none(self) -> bool {
        self.0.is_none()
    }

    pub fn spec(self) -> Option<FaultSpec> {
        self.0
    }

    /// Parse the CLI `--inject-fault` value: `<kind>@<site>[:<chunk>]`
    /// with kinds `panic` | `stall:<ms>` | `interp-error` and sites
    /// `interp` | `broadcaster` | `worker:<shard>`. The optional trailing
    /// `:<chunk>` selects which chunk ordinal fires (default 0).
    pub fn from_spec(s: &str) -> Result<Self> {
        let s = s.trim();
        let (kind_s, site_s) = match s.split_once('@') {
            Some(pair) => pair,
            None => bail!(
                "--inject-fault expects <kind>@<site>[:<chunk>] \
                 (e.g. panic@worker:1), got '{s}'"
            ),
        };
        let kind = match kind_s.split_once(':') {
            None if kind_s == "panic" => FaultKind::Panic,
            None if kind_s == "interp-error" => FaultKind::InterpError,
            Some(("stall", ms)) => match ms.parse::<u64>() {
                Ok(ms) => FaultKind::Stall(ms),
                Err(_) => bail!("--inject-fault stall wants milliseconds, got 'stall:{ms}'"),
            },
            _ => bail!(
                "unknown fault kind '{kind_s}' (panic | stall:<ms> | interp-error)"
            ),
        };
        let mut parts = site_s.split(':');
        let site = match parts.next() {
            Some("interp") => FaultSite::Interp,
            Some("broadcaster") => FaultSite::Broadcaster,
            Some("worker") => match parts.next().map(str::parse::<usize>) {
                Some(Ok(k)) => FaultSite::Worker(k),
                _ => bail!("--inject-fault worker site wants worker:<shard>, got '{site_s}'"),
            },
            _ => bail!(
                "unknown fault site in '{site_s}' (interp | broadcaster | worker:<shard>)"
            ),
        };
        let chunk = match parts.next() {
            None => 0,
            Some(c) => match c.parse::<u64>() {
                Ok(c) => c,
                Err(_) => bail!("--inject-fault chunk index must be an integer, got '{c}'"),
            },
        };
        if parts.next().is_some() {
            bail!("--inject-fault has trailing fields: '{s}'");
        }
        if kind == FaultKind::InterpError && site != FaultSite::Interp {
            bail!("interp-error faults only make sense at site 'interp', got '{site_s}'");
        }
        Ok(FaultPlan(Some(FaultSpec { kind, site, chunk })))
    }

    /// Arm the plan on a thread performing `roles`: the returned ticker
    /// fires iff the spec's site matches any of them. Threads tick it
    /// once per chunk they process.
    pub fn arm(self, roles: &[Role]) -> ArmedFault {
        let fault = self
            .0
            .filter(|spec| roles.iter().any(|&r| spec.matches(r)))
            .map(|spec| (spec.kind, spec.chunk));
        ArmedFault { fault, seen: 0 }
    }
}

/// A per-thread fault ticker produced by [`FaultPlan::arm`]. Call
/// [`ArmedFault::tick`] once per chunk; the fault fires on its chunk
/// ordinal, once, then disarms.
#[derive(Debug)]
pub struct ArmedFault {
    fault: Option<(FaultKind, u64)>,
    seen: u64,
}

impl ArmedFault {
    /// Advance the chunk counter, firing the fault if this is its chunk.
    /// `Panic` panics here, `Stall` sleeps here; `InterpError` is
    /// returned for the interpreter loop to surface as a run error.
    #[inline]
    pub fn tick(&mut self) -> Result<(), InjectedFault> {
        if self.fault.is_none() {
            return Ok(()); // un-injected hot path: one branch per chunk
        }
        self.tick_slow()
    }

    #[cold]
    fn tick_slow(&mut self) -> Result<(), InjectedFault> {
        let (kind, at) = self.fault.expect("checked by tick");
        let now = self.seen;
        self.seen += 1;
        if now != at {
            return Ok(());
        }
        self.fault = None; // fire once
        match kind {
            FaultKind::Panic => panic!("injected fault: panic at chunk {now}"),
            FaultKind::Stall(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
            FaultKind::InterpError => Err(InjectedFault { chunk: now }),
        }
    }
}

/// A per-app watchdog deadline (from `--app-timeout <secs>`), checked at
/// chunk boundaries. [`Deadline::none`] never expires.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Option<Instant>,
    secs: u64,
}

impl Deadline {
    /// The unarmed deadline: never expires, checks are one branch.
    pub fn none() -> Self {
        Deadline { at: None, secs: 0 }
    }

    /// Arm a deadline `secs` from now; `None` leaves it unarmed.
    pub fn after_secs(secs: Option<u64>) -> Self {
        match secs {
            Some(s) => Deadline { at: Some(Instant::now() + Duration::from_secs(s)), secs: s },
            None => Deadline::none(),
        }
    }

    pub fn is_armed(&self) -> bool {
        self.at.is_some()
    }

    /// `Err(TimeoutError)` once the deadline has passed.
    #[inline]
    pub fn check(&self) -> Result<(), TimeoutError> {
        match self.at {
            None => Ok(()),
            Some(at) if Instant::now() < at => Ok(()),
            Some(_) => Err(TimeoutError { secs: self.secs }),
        }
    }

    /// Time left before expiry — the bound for pool `recv_timeout` waits
    /// so a wedged analysis side cannot block the producer forever.
    /// `None` when unarmed; zero once expired.
    pub fn remaining(&self) -> Option<Duration> {
        self.at.map(|at| at.saturating_duration_since(Instant::now()))
    }
}

/// Per-app supervision options threaded from the CLI alongside
/// `TrafficOpts`: the fault plan and the watchdog timeout. `Copy` and
/// default-empty, so every existing entry point stays zero-cost.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SuperviseOpts {
    /// Deterministic fault injection (`--inject-fault`).
    pub fault: FaultPlan,
    /// Per-app watchdog in seconds (`--app-timeout`).
    pub timeout_s: Option<u64>,
}

impl SuperviseOpts {
    pub fn with_fault(mut self, fault: FaultPlan) -> Self {
        self.fault = fault;
        self
    }

    pub fn with_timeout_s(mut self, secs: Option<u64>) -> Self {
        self.timeout_s = secs;
        self
    }

    /// Arm the watchdog for one app run, starting now.
    pub fn deadline(&self) -> Deadline {
        Deadline::after_secs(self.timeout_s)
    }
}

/// Typed error for a watchdog expiry, recovered by the coordinator via
/// `anyhow::Error::downcast_ref` to classify the failure as `Timeout`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimeoutError {
    pub secs: u64,
}

impl fmt::Display for TimeoutError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "app exceeded --app-timeout {}s watchdog", self.secs)
    }
}

impl std::error::Error for TimeoutError {}

/// Typed error for an injected `interp-error` fault, recovered by the
/// coordinator via `downcast_ref` to classify the failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub chunk: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected fault: interpreter error at chunk {}", self.chunk)
    }
}

impl std::error::Error for InjectedFault {}

/// Typed error for a panic caught at a supervised boundary (the
/// interpreter thread under inline delivery, or a producer-side injected
/// panic), recovered by the coordinator via `downcast_ref` to classify
/// the failure as `WorkerPanic`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PanicError {
    /// Which supervised thread panicked (`interp`, `analysis`, ...).
    pub site: &'static str,
    pub message: String,
}

impl PanicError {
    pub fn new(site: &'static str, message: String) -> Self {
        PanicError { site, message }
    }
}

impl fmt::Display for PanicError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} thread panicked: {}", self.site, self.message)
    }
}

impl std::error::Error for PanicError {}

/// One analyzer shard (or the broadcaster feeding it) died mid-run. The
/// interp layer fills `shard` and `message`; the analysis layer maps the
/// shard index back to its metric-family names.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardFailure {
    /// Worker index in the run's shard plan (broadcaster failures are
    /// reported once per shard they starve).
    pub shard: usize,
    /// Metric-family names the shard owned (filled by the analysis
    /// layer; empty at the interp layer, which doesn't know the plan).
    pub families: Vec<String>,
    /// The panic payload or error text.
    pub message: String,
}

impl fmt::Display for ShardFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {} failed: {}", self.shard, self.message)?;
        if !self.families.is_empty() {
            write!(f, " (families: {})", self.families.join(", "))?;
        }
        Ok(())
    }
}

/// Render a `catch_unwind` payload as the panic message (panics carry
/// `&str` or `String`; anything else gets a placeholder).
pub fn panic_message(payload: Box<dyn Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind_and_site() {
        let p = FaultPlan::from_spec("panic@interp").unwrap().spec().unwrap();
        assert_eq!(p, FaultSpec { kind: FaultKind::Panic, site: FaultSite::Interp, chunk: 0 });
        let p = FaultPlan::from_spec("stall:250@broadcaster:3").unwrap().spec().unwrap();
        assert_eq!(
            p,
            FaultSpec { kind: FaultKind::Stall(250), site: FaultSite::Broadcaster, chunk: 3 }
        );
        let p = FaultPlan::from_spec("panic@worker:1:2").unwrap().spec().unwrap();
        assert_eq!(p, FaultSpec { kind: FaultKind::Panic, site: FaultSite::Worker(1), chunk: 2 });
        let p = FaultPlan::from_spec("interp-error@interp:5").unwrap().spec().unwrap();
        assert_eq!(
            p,
            FaultSpec { kind: FaultKind::InterpError, site: FaultSite::Interp, chunk: 5 }
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::from_spec("panic").is_err()); // no site
        assert!(FaultPlan::from_spec("explode@interp").is_err()); // bad kind
        assert!(FaultPlan::from_spec("stall@interp").is_err()); // stall wants ms
        assert!(FaultPlan::from_spec("stall:soon@interp").is_err());
        assert!(FaultPlan::from_spec("panic@disk").is_err()); // bad site
        assert!(FaultPlan::from_spec("panic@worker").is_err()); // worker wants index
        assert!(FaultPlan::from_spec("panic@worker:x").is_err());
        assert!(FaultPlan::from_spec("panic@interp:1:2").is_err()); // trailing
        // interp-error is an interpreter-loop error; other sites can't
        // surface it through the run result
        assert!(FaultPlan::from_spec("interp-error@worker:0").is_err());
        assert!(FaultPlan::from_spec("interp-error@broadcaster").is_err());
    }

    #[test]
    fn arming_matches_roles_with_worker_collapse() {
        let plan = FaultPlan::from_spec("panic@worker:4:1").unwrap();
        // sharded with 3 workers: worker 4 collapses onto index 1
        assert!(plan.arm(&[Role::Worker { index: 1, count: 3 }]).fault.is_some());
        assert!(plan.arm(&[Role::Worker { index: 0, count: 3 }]).fault.is_none());
        // offload/inline collapse: any worker site fires on the thread
        // doing all the worker roles
        assert!(plan.arm(&[Role::AnyWorker]).fault.is_some());
        assert!(plan.arm(&[Role::Interp]).fault.is_none());
        let plan = FaultPlan::from_spec("panic@broadcaster").unwrap();
        assert!(plan.arm(&[Role::Broadcaster, Role::AnyWorker]).fault.is_some());
        assert!(plan.arm(&[Role::Interp]).fault.is_none());
        assert!(FaultPlan::none().arm(&[Role::Interp, Role::Broadcaster]).fault.is_none());
    }

    #[test]
    fn armed_fault_fires_on_its_chunk_once() {
        let plan = FaultPlan::from_spec("interp-error@interp:2").unwrap();
        let mut armed = plan.arm(&[Role::Interp]);
        assert!(armed.tick().is_ok()); // chunk 0
        assert!(armed.tick().is_ok()); // chunk 1
        let err = armed.tick().unwrap_err(); // chunk 2: fires
        assert_eq!(err.chunk, 2);
        assert!(armed.tick().is_ok()); // disarmed after firing
        let mut none = FaultPlan::none().arm(&[Role::Interp]);
        for _ in 0..16 {
            assert!(none.tick().is_ok());
        }
    }

    #[test]
    fn deadline_checks_and_remaining() {
        let none = Deadline::none();
        assert!(!none.is_armed());
        assert!(none.check().is_ok());
        assert!(none.remaining().is_none());
        let armed = Deadline::after_secs(Some(3600));
        assert!(armed.is_armed());
        assert!(armed.check().is_ok());
        assert!(armed.remaining().unwrap() > Duration::from_secs(3000));
        let expired = Deadline { at: Some(Instant::now() - Duration::from_millis(1)), secs: 1 };
        assert_eq!(expired.check().unwrap_err(), TimeoutError { secs: 1 });
        assert_eq!(expired.remaining().unwrap(), Duration::ZERO);
    }

    #[test]
    fn panic_message_extracts_common_payloads() {
        let m = std::panic::catch_unwind(|| panic!("plain str")).unwrap_err();
        assert_eq!(panic_message(m), "plain str");
        let m = std::panic::catch_unwind(|| panic!("formatted {}", 7)).unwrap_err();
        assert_eq!(panic_message(m), "formatted 7");
    }

    #[test]
    fn supervise_opts_builders() {
        let o = SuperviseOpts::default();
        assert!(o.fault.is_none());
        assert!(o.timeout_s.is_none());
        assert!(!o.deadline().is_armed());
        let plan = FaultPlan::from_spec("panic@interp").unwrap();
        let o = SuperviseOpts::default().with_fault(plan).with_timeout_s(Some(9));
        assert_eq!(o.fault, plan);
        assert_eq!(o.timeout_s, Some(9));
        assert!(o.deadline().is_armed());
    }
}
