//! The virtual RISC-like instruction set.
//!
//! PISA works on LLVM IR; this repo substitutes a self-contained register
//! machine with the same *trace semantics*: typed arithmetic over virtual
//! registers, explicit loads/stores with byte addresses and sizes, and
//! basic-block structured control flow (DESIGN.md §Substitutions). Every
//! metric in `analysis/` is defined over the dynamic stream of these ops.

/// Operation kind, RISC-like. Integer ops operate on `i64`, float ops on
/// `f64`; conversions are explicit. Comparison results are `i64` 0/1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    // -- integer arithmetic / logic ---------------------------------------
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    // -- floating point ----------------------------------------------------
    FAdd,
    FSub,
    FMul,
    FDiv,
    FNeg,
    FSqrt,
    FExp,
    FAbs,
    FMin,
    FMax,
    // -- conversions ---------------------------------------------------------
    IToF,
    FToI,
    // -- comparisons (dst <- 0/1) -------------------------------------------
    CmpEq,
    CmpNe,
    CmpLt,
    CmpLe,
    CmpGt,
    CmpGe,
    FCmpEq,
    FCmpLt,
    FCmpLe,
    FCmpGt,
    // -- data movement --------------------------------------------------------
    /// dst <- immediate integer
    ConstI,
    /// dst <- immediate float
    ConstF,
    Mov,
    /// dst <- if src0 != 0 { src1 } else { src2 }
    Select,
    // -- memory ----------------------------------------------------------------
    /// dst <- mem[src0 (+ imm offset)], `size` bytes
    Load,
    /// mem[src1 (+ imm offset)] <- src0, `size` bytes
    Store,
}

/// Coarse categories used by the instruction-mix analyzer and by the machine
/// models' per-op cost tables (PISA's "instruction mix" metric).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    IntArith,
    FloatArith,
    Compare,
    Convert,
    DataMove,
    Load,
    Store,
    Control,
}

impl Op {
    pub fn class(self) -> OpClass {
        use Op::*;
        match self {
            Add | Sub | Mul | Div | Rem | And | Or | Xor | Shl | Shr => OpClass::IntArith,
            FAdd | FSub | FMul | FDiv | FNeg | FSqrt | FExp | FAbs | FMin | FMax => {
                OpClass::FloatArith
            }
            IToF | FToI => OpClass::Convert,
            CmpEq | CmpNe | CmpLt | CmpLe | CmpGt | CmpGe | FCmpEq | FCmpLt | FCmpLe | FCmpGt => {
                OpClass::Compare
            }
            ConstI | ConstF | Mov | Select => OpClass::DataMove,
            Load => OpClass::Load,
            Store => OpClass::Store,
        }
    }

    /// Number of register sources the op reads.
    pub fn arity(self) -> usize {
        use Op::*;
        match self {
            ConstI | ConstF => 0,
            Mov | FNeg | FSqrt | FExp | FAbs | IToF | FToI | Load => 1,
            Select => 3,
            Store => 2,
            _ => 2,
        }
    }

    /// Whether the op writes a destination register.
    pub fn has_dst(self) -> bool {
        !matches!(self, Op::Store)
    }

    /// Is this op a candidate lane in a vector unit (used by the DLP metric:
    /// only vectorizable ops contribute to data-level parallelism).
    pub fn vectorizable(self) -> bool {
        matches!(
            self.class(),
            OpClass::IntArith | OpClass::FloatArith | OpClass::Load | OpClass::Store
        )
    }

    pub fn mnemonic(self) -> &'static str {
        use Op::*;
        match self {
            Add => "add",
            Sub => "sub",
            Mul => "mul",
            Div => "div",
            Rem => "rem",
            And => "and",
            Or => "or",
            Xor => "xor",
            Shl => "shl",
            Shr => "shr",
            FAdd => "fadd",
            FSub => "fsub",
            FMul => "fmul",
            FDiv => "fdiv",
            FNeg => "fneg",
            FSqrt => "fsqrt",
            FExp => "fexp",
            FAbs => "fabs",
            FMin => "fmin",
            FMax => "fmax",
            IToF => "itof",
            FToI => "ftoi",
            CmpEq => "cmpeq",
            CmpNe => "cmpne",
            CmpLt => "cmplt",
            CmpLe => "cmple",
            CmpGt => "cmpgt",
            CmpGe => "cmpge",
            FCmpEq => "fcmpeq",
            FCmpLt => "fcmplt",
            FCmpLe => "fcmple",
            FCmpGt => "fcmpgt",
            ConstI => "consti",
            ConstF => "constf",
            Mov => "mov",
            Select => "select",
            Load => "load",
            Store => "store",
        }
    }

    /// Stable small integer id (used for per-opcode tables in the DLP
    /// analyzer and the trace encoding).
    pub fn index(self) -> usize {
        use Op::*;
        match self {
            Add => 0,
            Sub => 1,
            Mul => 2,
            Div => 3,
            Rem => 4,
            And => 5,
            Or => 6,
            Xor => 7,
            Shl => 8,
            Shr => 9,
            FAdd => 10,
            FSub => 11,
            FMul => 12,
            FDiv => 13,
            FNeg => 14,
            FSqrt => 15,
            FExp => 16,
            FAbs => 17,
            FMin => 18,
            FMax => 19,
            IToF => 20,
            FToI => 21,
            CmpEq => 22,
            CmpNe => 23,
            CmpLt => 24,
            CmpLe => 25,
            CmpGt => 26,
            CmpGe => 27,
            FCmpEq => 28,
            FCmpLt => 29,
            FCmpLe => 30,
            FCmpGt => 31,
            ConstI => 32,
            ConstF => 33,
            Mov => 34,
            Select => 35,
            Load => 36,
            Store => 37,
        }
    }

    pub const COUNT: usize = 38;

    pub fn from_index(i: usize) -> Option<Op> {
        use Op::*;
        const TABLE: [Op; Op::COUNT] = [
            Add, Sub, Mul, Div, Rem, And, Or, Xor, Shl, Shr, FAdd, FSub, FMul, FDiv, FNeg, FSqrt,
            FExp, FAbs, FMin, FMax, IToF, FToI, CmpEq, CmpNe, CmpLt, CmpLe, CmpGt, CmpGe, FCmpEq,
            FCmpLt, FCmpLe, FCmpGt, ConstI, ConstF, Mov, Select, Load, Store,
        ];
        TABLE.get(i).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        for i in 0..Op::COUNT {
            let op = Op::from_index(i).expect("index in range");
            assert_eq!(op.index(), i);
        }
        assert!(Op::from_index(Op::COUNT).is_none());
    }

    #[test]
    fn arity_and_dst() {
        assert_eq!(Op::Store.arity(), 2);
        assert!(!Op::Store.has_dst());
        assert_eq!(Op::Select.arity(), 3);
        assert_eq!(Op::ConstI.arity(), 0);
        assert!(Op::Load.has_dst());
    }

    #[test]
    fn classes() {
        assert_eq!(Op::Add.class(), OpClass::IntArith);
        assert_eq!(Op::FExp.class(), OpClass::FloatArith);
        assert_eq!(Op::Load.class(), OpClass::Load);
        assert!(Op::FMul.vectorizable());
        assert!(!Op::Mov.vectorizable());
    }
}
