//! Structural verifier — run on every workload program in tests and by the
//! coordinator before profiling (a malformed program would silently skew
//! every metric downstream).

use super::func::Program;
use super::instr::{Imm, Terminator};
use super::op::Op;

/// A structural defect in a program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyError {
    RegOutOfRange { block: usize, instr: usize, reg: u16 },
    BadArity { block: usize, instr: usize, got: u8, want: usize },
    MissingImm { block: usize, instr: usize },
    BadAccessSize { block: usize, instr: usize, size: u8 },
    BranchTargetOutOfRange { block: usize, target: u32 },
    BranchCondOutOfRange { block: usize, reg: u16 },
    RetOutOfRange { block: usize, reg: u16 },
    StoreWithDst { block: usize, instr: usize },
    BufferOverlap { a: String, b: String },
    EmptyProgram,
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for VerifyError {}

/// Check register ranges, arities, immediates, access sizes, branch targets
/// and buffer disjointness. Returns all defects, not just the first.
pub fn verify(p: &Program) -> Vec<VerifyError> {
    let mut errs = Vec::new();
    if p.func.blocks.is_empty() {
        errs.push(VerifyError::EmptyProgram);
        return errs;
    }
    let n_regs = p.func.n_regs;
    let n_blocks = p.func.blocks.len() as u32;

    for (bi, block) in p.func.blocks.iter().enumerate() {
        for (ii, ins) in block.instrs.iter().enumerate() {
            if ins.n_srcs as usize != ins.op.arity() {
                errs.push(VerifyError::BadArity {
                    block: bi,
                    instr: ii,
                    got: ins.n_srcs,
                    want: ins.op.arity(),
                });
            }
            for &r in ins.sources() {
                if r >= n_regs {
                    errs.push(VerifyError::RegOutOfRange { block: bi, instr: ii, reg: r });
                }
            }
            if let Some(d) = ins.dst {
                if d >= n_regs {
                    errs.push(VerifyError::RegOutOfRange { block: bi, instr: ii, reg: d });
                }
                if ins.op == Op::Store {
                    errs.push(VerifyError::StoreWithDst { block: bi, instr: ii });
                }
            }
            match ins.op {
                Op::ConstI => {
                    if !matches!(ins.imm, Imm::I(_)) {
                        errs.push(VerifyError::MissingImm { block: bi, instr: ii });
                    }
                }
                Op::ConstF => {
                    if !matches!(ins.imm, Imm::F(_)) {
                        errs.push(VerifyError::MissingImm { block: bi, instr: ii });
                    }
                }
                Op::Load | Op::Store => {
                    if !matches!(ins.size, 1 | 2 | 4 | 8) {
                        errs.push(VerifyError::BadAccessSize {
                            block: bi,
                            instr: ii,
                            size: ins.size,
                        });
                    }
                }
                _ => {}
            }
        }
        match &block.term {
            Terminator::Jmp(t) => {
                if *t >= n_blocks {
                    errs.push(VerifyError::BranchTargetOutOfRange { block: bi, target: *t });
                }
            }
            Terminator::Br { cond, then_, else_ } => {
                if *cond >= n_regs {
                    errs.push(VerifyError::BranchCondOutOfRange { block: bi, reg: *cond });
                }
                for t in [*then_, *else_] {
                    if t >= n_blocks {
                        errs.push(VerifyError::BranchTargetOutOfRange { block: bi, target: t });
                    }
                }
            }
            Terminator::Ret(Some(r)) => {
                if *r >= n_regs {
                    errs.push(VerifyError::RetOutOfRange { block: bi, reg: *r });
                }
            }
            Terminator::Ret(None) => {}
        }
    }

    // buffer disjointness
    let mut sorted: Vec<_> = p.buffers.iter().collect();
    sorted.sort_by_key(|b| b.base);
    for w in sorted.windows(2) {
        if w[0].base + w[0].len_bytes > w[1].base {
            errs.push(VerifyError::BufferOverlap {
                a: w[0].name.clone(),
                b: w[1].name.clone(),
            });
        }
    }
    errs
}

/// Panic-on-defect wrapper for tests and workload constructors.
pub fn verify_ok(p: &Program) {
    let errs = verify(p);
    assert!(errs.is_empty(), "IR verification failed: {errs:?}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;
    use crate::ir::instr::{Imm, Instr};

    #[test]
    fn clean_program_verifies() {
        let mut b = ProgramBuilder::new("ok");
        let buf = b.alloc_f64_init("a", &[1.0, 2.0]);
        let n = b.const_i(2);
        b.counted_loop(n, |b, i| {
            let v = b.load_f64(buf, i);
            let w = b.fadd(v, v);
            b.store_f64(buf, i, w);
        });
        verify_ok(&b.finish(None));
    }

    #[test]
    fn catches_reg_out_of_range() {
        let mut b = ProgramBuilder::new("bad");
        let x = b.const_i(0);
        b.add(x, x);
        let mut p = b.finish(None);
        p.func.blocks[0].instrs[1].srcs[0] = 999;
        assert!(verify(&p)
            .iter()
            .any(|e| matches!(e, VerifyError::RegOutOfRange { .. })));
    }

    #[test]
    fn catches_bad_branch_target() {
        let mut b = ProgramBuilder::new("bad");
        let n = b.const_i(1);
        b.counted_loop(n, |_b, _i| {});
        let mut p = b.finish(None);
        p.func.blocks[1].term = crate::ir::instr::Terminator::Jmp(99);
        assert!(verify(&p)
            .iter()
            .any(|e| matches!(e, VerifyError::BranchTargetOutOfRange { .. })));
    }

    #[test]
    fn catches_bad_access_size() {
        let mut b = ProgramBuilder::new("bad");
        let x = b.const_i(0x1000);
        let mut p = b.finish(None);
        p.func.blocks[0].instrs.push(Instr {
            op: crate::ir::op::Op::Load,
            dst: Some(x),
            srcs: [x, 0, 0],
            n_srcs: 1,
            imm: Imm::None,
            size: 3,
            fp: false,
        });
        assert!(verify(&p)
            .iter()
            .any(|e| matches!(e, VerifyError::BadAccessSize { .. })));
    }
}
