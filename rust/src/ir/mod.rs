//! The hardware-agnostic mini-IR PISA-NMC analyzes.
//!
//! PISA instruments LLVM IR; this repo substitutes a self-contained
//! register-machine IR with identical *trace semantics* (see DESIGN.md
//! §Substitutions): RISC-like typed ops over virtual registers, explicit
//! byte-addressed loads/stores, and basic-block structured control flow.
//! Workloads are authored through [`builder::ProgramBuilder`], validated by
//! [`verify`], executed (and instrumented) by [`crate::interp`].

pub mod builder;
pub mod func;
pub mod instr;
pub mod op;
pub mod print;
pub mod verify;

pub use builder::{BufRef, ProgramBuilder};
pub use func::{Block, Buffer, Function, LoopInfo, Program};
pub use instr::{BlockId, Imm, Instr, Reg, Terminator, Value};
pub use op::{Op, OpClass};
