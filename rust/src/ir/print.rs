//! Textual IR dump — for docs, goldens and debugging workload kernels.

use super::func::Program;
use super::instr::{Imm, Terminator};
use std::fmt::Write;

/// Render a program in a compact LLVM-flavoured text form.
pub fn print_program(p: &Program) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "; program {} ({} regs)", p.func.name, p.func.n_regs);
    for b in &p.buffers {
        let _ = writeln!(
            s,
            "; buffer {:<12} base=0x{:x} bytes={} elem={}",
            b.name, b.base, b.len_bytes, b.elem
        );
    }
    for (bi, block) in p.func.blocks.iter().enumerate() {
        let _ = writeln!(s, "{}: ; bb{}", block.name, bi);
        for ins in &block.instrs {
            let mut line = String::from("  ");
            if let Some(d) = ins.dst {
                let _ = write!(line, "r{d} = ");
            }
            let _ = write!(line, "{}", ins.op.mnemonic());
            match ins.imm {
                Imm::I(v) => {
                    let _ = write!(line, " #{v}");
                }
                Imm::F(v) => {
                    let _ = write!(line, " #{v}");
                }
                Imm::None => {}
            }
            for r in ins.sources() {
                let _ = write!(line, " r{r}");
            }
            if ins.size != 0 {
                let _ = write!(line, " [{}B]", ins.size);
            }
            let _ = writeln!(s, "{line}");
        }
        match &block.term {
            Terminator::Jmp(t) => {
                let _ = writeln!(s, "  jmp bb{t}");
            }
            Terminator::Br { cond, then_, else_ } => {
                let _ = writeln!(s, "  br r{cond}, bb{then_}, bb{else_}");
            }
            Terminator::Ret(Some(r)) => {
                let _ = writeln!(s, "  ret r{r}");
            }
            Terminator::Ret(None) => {
                let _ = writeln!(s, "  ret");
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;

    #[test]
    fn print_contains_structure() {
        let mut b = ProgramBuilder::new("demo");
        let a = b.alloc_f64_init("a", &[1.0]);
        let zero = b.const_i(0);
        let v = b.load_f64(a, zero);
        let w = b.fadd(v, v);
        b.store_f64(a, zero, w);
        let p = b.finish(None);
        let text = print_program(&p);
        assert!(text.contains("program demo"));
        assert!(text.contains("buffer a"));
        assert!(text.contains("fadd"));
        assert!(text.contains("[8B]"));
        assert!(text.contains("ret"));
    }
}
