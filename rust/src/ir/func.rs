//! Basic blocks, functions and programs (the "module" level of the mini-IR).

use super::instr::{BlockId, Instr, Reg, Terminator};

/// A straight-line instruction sequence with a single terminator — the unit
/// the BBLP/PBBLP analyzers treat as an atomic sequential task (paper §II-B).
#[derive(Debug, Clone)]
pub struct Block {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub term: Terminator,
}

/// A kernel: one register file, a block list, entry at block 0.
#[derive(Debug, Clone)]
pub struct Function {
    pub name: String,
    pub blocks: Vec<Block>,
    pub n_regs: u16,
}

impl Function {
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id as usize]
    }

    /// Static instruction count (terminators excluded).
    pub fn static_instrs(&self) -> usize {
        self.blocks.iter().map(|b| b.instrs.len()).sum()
    }

    /// Back edges (src → dst with dst appearing earlier in reverse post
    /// order). Block ids from the builder are emission-ordered, and the
    /// builder only creates loops through its structured loop helper, so a
    /// branch to a lower-or-equal id is a back edge. The PBBLP analyzer uses
    /// these to identify loop headers.
    pub fn back_edges(&self) -> Vec<(BlockId, BlockId)> {
        let mut edges = Vec::new();
        for (i, b) in self.blocks.iter().enumerate() {
            for succ in b.term.successors() {
                if succ as usize <= i {
                    edges.push((i as BlockId, succ));
                }
            }
        }
        edges
    }
}

/// A named data buffer in the flat byte-addressed memory image. Buffers are
/// allocated consecutively with alignment padding by the `Program`.
#[derive(Debug, Clone)]
pub struct Buffer {
    pub name: String,
    pub base: u64,
    pub len_bytes: u64,
    /// Element size in bytes (for pretty-printing / oracles).
    pub elem: u8,
}

/// Structured-loop metadata recorded by the builder (the moral equivalent of
/// LLVM's LoopInfo, which PISA's pass reads statically). The PBBLP analyzer
/// uses `counter` to exclude induction-variable dependencies when deciding
/// whether loop iterations are data-parallel.
#[derive(Debug, Clone, Copy)]
pub struct LoopInfo {
    pub header: BlockId,
    pub body: BlockId,
    pub exit: BlockId,
    /// The induction register (incremented once per iteration in the latch).
    pub counter: Reg,
}

/// A full analyzable program: one entry function plus its memory image
/// layout. Initial data is installed by the interpreter from `data`.
#[derive(Debug, Clone)]
pub struct Program {
    pub func: Function,
    pub buffers: Vec<Buffer>,
    /// Total bytes of the memory image (including alignment padding).
    pub mem_bytes: u64,
    /// Initial memory contents: (base address, bytes).
    pub data: Vec<(u64, Vec<u8>)>,
    /// Structured loops, outermost-first in emission order.
    pub loops: Vec<LoopInfo>,
}

impl Program {
    pub fn buffer(&self, name: &str) -> Option<&Buffer> {
        self.buffers.iter().find(|b| b.name == name)
    }
}

/// Convenience for analyzers that need a register count without the whole
/// function.
pub fn max_reg(f: &Function) -> Reg {
    f.n_regs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::ProgramBuilder;

    #[test]
    fn back_edges_found_for_loop() {
        let mut b = ProgramBuilder::new("loop_test");
        let n = b.const_i(4);
        b.counted_loop(n, |_b, _i| {});
        let p = b.finish(None);
        assert!(
            !p.func.back_edges().is_empty(),
            "counted_loop must create a back edge"
        );
    }

    #[test]
    fn static_instr_count() {
        let mut b = ProgramBuilder::new("s");
        let x = b.const_i(1);
        let y = b.const_i(2);
        b.add(x, y);
        let p = b.finish(None);
        assert_eq!(p.func.static_instrs(), 3);
    }
}
