//! Structured program builder — the public authoring API for workloads.
//!
//! The builder plays the role of clang in the PISA flow: it is how C-like
//! kernels become analyzable IR. Control flow is structured (counted loops,
//! while loops, if/else); the builder records `LoopInfo` for every loop it
//! emits, which is what the PBBLP analyzer consumes in lieu of LLVM's
//! LoopInfo pass.
//!
//! ```no_run
//! use pisa_nmc::ir::builder::ProgramBuilder;
//! let mut b = ProgramBuilder::new("dot");
//! let a = b.alloc_f64_init("a", &[1.0, 2.0, 3.0]);
//! let x = b.alloc_f64_init("x", &[4.0, 5.0, 6.0]);
//! let acc = b.const_f(0.0);
//! let n = b.const_i(3);
//! b.counted_loop(n, |b, i| {
//!     let ai = b.load_f64(a, i);
//!     let xi = b.load_f64(x, i);
//!     let p = b.fmul(ai, xi);
//!     let s = b.fadd(acc, p);
//!     b.assign(acc, s);
//! });
//! let prog = b.finish(Some(acc));
//! ```

use super::func::{Block, Buffer, Function, LoopInfo, Program};
use super::instr::{BlockId, Imm, Instr, Reg, Terminator};
use super::op::Op;

/// Typed handle to an allocated buffer. `Copy` so closures can capture it.
#[derive(Debug, Clone, Copy)]
pub struct BufRef {
    pub base: u64,
    pub elem: u8,
    pub len: u64,
}

impl BufRef {
    pub fn len_bytes(&self) -> u64 {
        self.len * self.elem as u64
    }
}

struct ProtoBlock {
    name: String,
    instrs: Vec<Instr>,
    term: Option<Terminator>,
}

/// Builder state. Blocks are created eagerly and terminators patched as the
/// structured constructs close.
pub struct ProgramBuilder {
    name: String,
    blocks: Vec<ProtoBlock>,
    cur: BlockId,
    next_reg: u16,
    buffers: Vec<Buffer>,
    data: Vec<(u64, Vec<u8>)>,
    next_addr: u64,
    loops: Vec<LoopInfo>,
}

/// Buffers start above the null page and are 64-byte aligned so line-granule
/// analyses don't see accidental buffer overlap inside one cache line.
const BASE_ADDR: u64 = 0x1_0000;
const ALIGN: u64 = 64;

impl ProgramBuilder {
    pub fn new(name: &str) -> Self {
        ProgramBuilder {
            name: name.to_string(),
            blocks: vec![ProtoBlock {
                name: "entry".into(),
                instrs: Vec::new(),
                term: None,
            }],
            cur: 0,
            next_reg: 0,
            buffers: Vec::new(),
            data: Vec::new(),
            next_addr: BASE_ADDR,
            loops: Vec::new(),
        }
    }

    // ---- registers & raw emission ---------------------------------------

    fn fresh(&mut self) -> Reg {
        let r = self.next_reg;
        self.next_reg = self
            .next_reg
            .checked_add(1)
            .expect("register file overflow (>65535 virtual registers)");
        r
    }

    fn push(&mut self, i: Instr) {
        self.blocks[self.cur as usize].instrs.push(i);
    }

    /// Emit `op` over `srcs` into a fresh destination register.
    pub fn emit(&mut self, op: Op, srcs: &[Reg]) -> Reg {
        debug_assert_eq!(srcs.len(), op.arity(), "{:?} arity", op);
        debug_assert!(op.has_dst(), "use emit_void for {:?}", op);
        let dst = self.fresh();
        self.emit_into(dst, op, srcs);
        dst
    }

    /// Emit `op` into an existing destination (register mutation — used for
    /// loop-carried accumulators).
    pub fn emit_into(&mut self, dst: Reg, op: Op, srcs: &[Reg]) {
        let mut s = [0 as Reg; 3];
        s[..srcs.len()].copy_from_slice(srcs);
        self.push(Instr {
            op,
            dst: Some(dst),
            srcs: s,
            n_srcs: srcs.len() as u8,
            imm: Imm::None,
            size: 0,
            fp: false,
        });
    }

    // ---- constants & moves ----------------------------------------------

    pub fn const_i(&mut self, v: i64) -> Reg {
        let dst = self.fresh();
        self.push(Instr {
            op: Op::ConstI,
            dst: Some(dst),
            srcs: [0; 3],
            n_srcs: 0,
            imm: Imm::I(v),
            size: 0,
            fp: false,
        });
        dst
    }

    pub fn const_f(&mut self, v: f64) -> Reg {
        let dst = self.fresh();
        self.push(Instr {
            op: Op::ConstF,
            dst: Some(dst),
            srcs: [0; 3],
            n_srcs: 0,
            imm: Imm::F(v),
            size: 0,
            fp: false,
        });
        dst
    }

    /// `dst <- src` into an existing register (loop-carried update).
    pub fn assign(&mut self, dst: Reg, src: Reg) {
        self.emit_into(dst, Op::Mov, &[src]);
    }

    // ---- binary/unary sugar -----------------------------------------------

    pub fn add(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Add, &[a, b])
    }
    pub fn sub(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Sub, &[a, b])
    }
    pub fn mul(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Mul, &[a, b])
    }
    pub fn div(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Div, &[a, b])
    }
    pub fn rem(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Rem, &[a, b])
    }
    pub fn and(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::And, &[a, b])
    }
    pub fn xor(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Xor, &[a, b])
    }
    pub fn shl(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Shl, &[a, b])
    }
    pub fn shr(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::Shr, &[a, b])
    }
    pub fn fadd(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FAdd, &[a, b])
    }
    pub fn fsub(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FSub, &[a, b])
    }
    pub fn fmul(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FMul, &[a, b])
    }
    pub fn fdiv(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FDiv, &[a, b])
    }
    pub fn fneg(&mut self, a: Reg) -> Reg {
        self.emit(Op::FNeg, &[a])
    }
    pub fn fsqrt(&mut self, a: Reg) -> Reg {
        self.emit(Op::FSqrt, &[a])
    }
    pub fn fexp(&mut self, a: Reg) -> Reg {
        self.emit(Op::FExp, &[a])
    }
    pub fn fabs(&mut self, a: Reg) -> Reg {
        self.emit(Op::FAbs, &[a])
    }
    pub fn fmin(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FMin, &[a, b])
    }
    pub fn fmax(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FMax, &[a, b])
    }
    pub fn itof(&mut self, a: Reg) -> Reg {
        self.emit(Op::IToF, &[a])
    }
    pub fn ftoi(&mut self, a: Reg) -> Reg {
        self.emit(Op::FToI, &[a])
    }
    pub fn cmp_lt(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::CmpLt, &[a, b])
    }
    pub fn cmp_le(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::CmpLe, &[a, b])
    }
    pub fn cmp_gt(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::CmpGt, &[a, b])
    }
    pub fn cmp_eq(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::CmpEq, &[a, b])
    }
    pub fn cmp_ne(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::CmpNe, &[a, b])
    }
    pub fn fcmp_lt(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FCmpLt, &[a, b])
    }
    pub fn fcmp_gt(&mut self, a: Reg, b: Reg) -> Reg {
        self.emit(Op::FCmpGt, &[a, b])
    }
    pub fn select(&mut self, c: Reg, t: Reg, f: Reg) -> Reg {
        self.emit(Op::Select, &[c, t, f])
    }

    /// a + imm (emits a const + add; common enough to deserve sugar).
    pub fn add_i(&mut self, a: Reg, imm: i64) -> Reg {
        let c = self.const_i(imm);
        self.add(a, c)
    }

    pub fn mul_i(&mut self, a: Reg, imm: i64) -> Reg {
        let c = self.const_i(imm);
        self.mul(a, c)
    }

    // ---- memory -----------------------------------------------------------

    fn alloc_raw(&mut self, name: &str, len: u64, elem: u8, init: Option<Vec<u8>>) -> BufRef {
        let bytes = len * elem as u64;
        let base = self.next_addr;
        self.next_addr += (bytes + ALIGN - 1) / ALIGN * ALIGN;
        self.buffers.push(Buffer {
            name: name.to_string(),
            base,
            len_bytes: bytes,
            elem,
        });
        if let Some(d) = init {
            assert_eq!(d.len() as u64, bytes);
            self.data.push((base, d));
        }
        BufRef { base, elem, len }
    }

    /// Zero-initialized f64 array.
    pub fn alloc_f64(&mut self, name: &str, len: usize) -> BufRef {
        self.alloc_raw(name, len as u64, 8, Some(vec![0u8; len * 8]))
    }

    pub fn alloc_f64_init(&mut self, name: &str, data: &[f64]) -> BufRef {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.alloc_raw(name, data.len() as u64, 8, Some(bytes))
    }

    pub fn alloc_i64(&mut self, name: &str, len: usize) -> BufRef {
        self.alloc_raw(name, len as u64, 8, Some(vec![0u8; len * 8]))
    }

    pub fn alloc_i64_init(&mut self, name: &str, data: &[i64]) -> BufRef {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.alloc_raw(name, data.len() as u64, 8, Some(bytes))
    }

    /// Byte address `buf.base + idx * buf.elem` as a register.
    pub fn addr_of(&mut self, buf: BufRef, idx: Reg) -> Reg {
        let off = self.mul_i(idx, buf.elem as i64);
        self.add_i(off, buf.base as i64)
    }

    fn load_sized(&mut self, buf: BufRef, idx: Reg, size: u8, fp: bool) -> Reg {
        let addr = self.addr_of(buf, idx);
        let dst = self.fresh();
        self.push(Instr {
            op: Op::Load,
            dst: Some(dst),
            srcs: [addr, 0, 0],
            n_srcs: 1,
            imm: Imm::None,
            size,
            fp,
        });
        dst
    }

    fn store_sized(&mut self, buf: BufRef, idx: Reg, val: Reg, size: u8, fp: bool) {
        let addr = self.addr_of(buf, idx);
        self.push(Instr {
            op: Op::Store,
            dst: None,
            srcs: [val, addr, 0],
            n_srcs: 2,
            imm: Imm::None,
            size,
            fp,
        });
    }

    pub fn load_f64(&mut self, buf: BufRef, idx: Reg) -> Reg {
        self.load_sized(buf, idx, 8, true)
    }
    pub fn store_f64(&mut self, buf: BufRef, idx: Reg, val: Reg) {
        self.store_sized(buf, idx, val, 8, true)
    }
    pub fn load_i64(&mut self, buf: BufRef, idx: Reg) -> Reg {
        self.load_sized(buf, idx, 8, false)
    }
    pub fn store_i64(&mut self, buf: BufRef, idx: Reg, val: Reg) {
        self.store_sized(buf, idx, val, 8, false)
    }

    /// Row-major 2D index: `buf[i * ncols + j]`.
    pub fn idx2(&mut self, i: Reg, j: Reg, ncols: i64) -> Reg {
        let r = self.mul_i(i, ncols);
        self.add(r, j)
    }

    pub fn load_f64_2d(&mut self, buf: BufRef, i: Reg, j: Reg, ncols: i64) -> Reg {
        let idx = self.idx2(i, j, ncols);
        self.load_f64(buf, idx)
    }

    pub fn store_f64_2d(&mut self, buf: BufRef, i: Reg, j: Reg, ncols: i64, val: Reg) {
        let idx = self.idx2(i, j, ncols);
        self.store_f64(buf, idx, val)
    }

    // ---- control flow ------------------------------------------------------

    fn new_block(&mut self, name: String) -> BlockId {
        self.blocks.push(ProtoBlock {
            name,
            instrs: Vec::new(),
            term: None,
        });
        (self.blocks.len() - 1) as BlockId
    }

    fn seal(&mut self, term: Terminator) {
        let b = &mut self.blocks[self.cur as usize];
        assert!(b.term.is_none(), "block {} already sealed", b.name);
        b.term = Some(term);
    }

    /// `for i in 0..n` — the workhorse. Returns after positioning the builder
    /// at the loop exit block.
    pub fn counted_loop(&mut self, n: Reg, body: impl FnOnce(&mut Self, Reg)) {
        let zero = self.const_i(0);
        self.loop_range(zero, n, body)
    }

    /// `for i in lo..hi` (step 1).
    pub fn loop_range(&mut self, lo: Reg, hi: Reg, body: impl FnOnce(&mut Self, Reg)) {
        let id = self.loops.len();
        let i = self.fresh();
        self.emit_into(i, Op::Mov, &[lo]);

        let header = self.new_block(format!("loop{id}.header"));
        let body_bb = self.new_block(format!("loop{id}.body"));
        let exit = self.new_block(format!("loop{id}.exit"));

        self.seal(Terminator::Jmp(header));

        self.cur = header;
        let cond = self.cmp_lt(i, hi);
        self.seal(Terminator::Br {
            cond,
            then_: body_bb,
            else_: exit,
        });

        self.loops.push(LoopInfo {
            header,
            body: body_bb,
            exit,
            counter: i,
        });

        self.cur = body_bb;
        body(self, i);
        // latch: i += 1; jmp header (in whatever block the body ended in)
        let one = self.const_i(1);
        self.emit_into(i, Op::Add, &[i, one]);
        self.seal(Terminator::Jmp(header));

        self.cur = exit;
    }

    /// `while cond()` — cond is re-evaluated in the header each iteration.
    pub fn while_loop(
        &mut self,
        cond: impl Fn(&mut Self) -> Reg,
        body: impl FnOnce(&mut Self),
    ) {
        let id = self.loops.len();
        let header = self.new_block(format!("while{id}.header"));
        let body_bb = self.new_block(format!("while{id}.body"));
        let exit = self.new_block(format!("while{id}.exit"));

        self.seal(Terminator::Jmp(header));

        self.cur = header;
        let c = cond(self);
        self.seal(Terminator::Br {
            cond: c,
            then_: body_bb,
            else_: exit,
        });

        // while-loops have no structured induction register; record u16::MAX
        // so PBBLP treats every loop-carried dep as real.
        self.loops.push(LoopInfo {
            header,
            body: body_bb,
            exit,
            counter: Reg::MAX,
        });

        self.cur = body_bb;
        body(self);
        self.seal(Terminator::Jmp(header));

        self.cur = exit;
    }

    /// `if cond { then }`.
    pub fn if_then(&mut self, cond: Reg, then: impl FnOnce(&mut Self)) {
        let then_bb = self.new_block("if.then".into());
        let join = self.new_block("if.join".into());
        self.seal(Terminator::Br {
            cond,
            then_: then_bb,
            else_: join,
        });
        self.cur = then_bb;
        then(self);
        self.seal(Terminator::Jmp(join));
        self.cur = join;
    }

    /// `if cond { then } else { other }`.
    pub fn if_then_else(
        &mut self,
        cond: Reg,
        then: impl FnOnce(&mut Self),
        other: impl FnOnce(&mut Self),
    ) {
        let then_bb = self.new_block("if.then".into());
        let else_bb = self.new_block("if.else".into());
        let join = self.new_block("if.join".into());
        self.seal(Terminator::Br {
            cond,
            then_: then_bb,
            else_: else_bb,
        });
        self.cur = then_bb;
        then(self);
        self.seal(Terminator::Jmp(join));
        self.cur = else_bb;
        other(self);
        self.seal(Terminator::Jmp(join));
        self.cur = join;
    }

    // ---- finish -------------------------------------------------------------

    pub fn finish(mut self, ret: Option<Reg>) -> Program {
        self.seal(Terminator::Ret(ret));
        let blocks = self
            .blocks
            .into_iter()
            .map(|p| Block {
                name: p.name,
                instrs: p.instrs,
                term: p.term.expect("unterminated block"),
            })
            .collect();
        Program {
            func: Function {
                name: self.name,
                blocks,
                n_regs: self.next_reg,
            },
            buffers: self.buffers,
            mem_bytes: self.next_addr,
            data: self.data,
            loops: self.loops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_straight_line() {
        let mut b = ProgramBuilder::new("t");
        let x = b.const_f(2.0);
        let y = b.const_f(3.0);
        let z = b.fmul(x, y);
        let p = b.finish(Some(z));
        assert_eq!(p.func.blocks.len(), 1);
        assert_eq!(p.func.blocks[0].instrs.len(), 3);
        assert!(matches!(p.func.blocks[0].term, Terminator::Ret(Some(_))));
    }

    #[test]
    fn counted_loop_shape() {
        let mut b = ProgramBuilder::new("t");
        let n = b.const_i(10);
        b.counted_loop(n, |b, i| {
            b.add_i(i, 1);
        });
        let p = b.finish(None);
        // entry, header, body, exit
        assert_eq!(p.func.blocks.len(), 4);
        assert_eq!(p.loops.len(), 1);
        let li = p.loops[0];
        assert_eq!(li.header, 1);
        assert_eq!(li.body, 2);
        assert_eq!(li.exit, 3);
        assert_ne!(li.counter, Reg::MAX);
    }

    #[test]
    fn nested_loops_record_two_infos() {
        let mut b = ProgramBuilder::new("t");
        let n = b.const_i(3);
        b.counted_loop(n, |b, _i| {
            let m = b.const_i(2);
            b.counted_loop(m, |b, j| {
                b.add_i(j, 0);
            });
        });
        let p = b.finish(None);
        assert_eq!(p.loops.len(), 2);
    }

    #[test]
    fn buffers_aligned_and_disjoint() {
        let mut b = ProgramBuilder::new("t");
        let a = b.alloc_f64("a", 3);
        let c = b.alloc_f64("c", 100);
        assert_eq!(a.base % 64, 0);
        assert_eq!(c.base % 64, 0);
        assert!(a.base + a.len_bytes() <= c.base);
    }

    #[test]
    fn if_then_else_blocks() {
        let mut b = ProgramBuilder::new("t");
        let c = b.const_i(1);
        b.if_then_else(c, |b| { b.const_i(10); }, |b| { b.const_i(20); });
        let p = b.finish(None);
        assert_eq!(p.func.blocks.len(), 4); // entry, then, else, join
    }
}
