//! Instructions, terminators and the small value model.

use super::op::Op;

/// Virtual register id (per-function register file).
pub type Reg = u16;
/// Basic-block id (index into `Function::blocks`).
pub type BlockId = u32;

/// Runtime value: the machine is loosely typed with explicit conversions,
/// like LLVM's `i64`/`double` subset PISA traces reduce to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    I(i64),
    F(f64),
}

impl Value {
    pub fn as_i(self) -> i64 {
        match self {
            Value::I(v) => v,
            Value::F(v) => v as i64,
        }
    }
    pub fn as_f(self) -> f64 {
        match self {
            Value::I(v) => v as f64,
            Value::F(v) => v,
        }
    }
    pub fn truthy(self) -> bool {
        match self {
            Value::I(v) => v != 0,
            Value::F(v) => v != 0.0,
        }
    }
}

/// Immediate payload for `ConstI`/`ConstF` and load/store offsets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Imm {
    None,
    I(i64),
    F(f64),
}

/// One non-terminator instruction. `srcs` are read in order; memory ops
/// carry an access `size` in bytes (1/2/4/8) and a constant byte offset in
/// `imm` so address arithmetic stays explicit but compact.
#[derive(Debug, Clone)]
pub struct Instr {
    pub op: Op,
    pub dst: Option<Reg>,
    pub srcs: [Reg; 3],
    pub n_srcs: u8,
    pub imm: Imm,
    /// Access size in bytes for Load/Store; 0 otherwise.
    pub size: u8,
    /// For 8-byte Load/Store: interpret the memory bits as f64 (true) or
    /// i64 (false). Narrower accesses are always integer.
    pub fp: bool,
}

impl Instr {
    pub fn sources(&self) -> &[Reg] {
        &self.srcs[..self.n_srcs as usize]
    }
}

/// Block terminator. Every block ends in exactly one of these; conditional
/// branches are what the branch-entropy analyzer observes.
#[derive(Debug, Clone)]
pub enum Terminator {
    /// Unconditional jump.
    Jmp(BlockId),
    /// if reg != 0 goto `then_`, else `else_`.
    Br {
        cond: Reg,
        then_: BlockId,
        else_: BlockId,
    },
    /// Return from the kernel; optional value register.
    Ret(Option<Reg>),
}

impl Terminator {
    pub fn successors(&self) -> Vec<BlockId> {
        match self {
            Terminator::Jmp(b) => vec![*b],
            Terminator::Br { then_, else_, .. } => vec![*then_, *else_],
            Terminator::Ret(_) => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_conversions() {
        assert_eq!(Value::I(3).as_f(), 3.0);
        assert_eq!(Value::F(2.9).as_i(), 2);
        assert!(Value::I(1).truthy());
        assert!(!Value::F(0.0).truthy());
    }

    #[test]
    fn terminator_successors() {
        assert_eq!(Terminator::Jmp(4).successors(), vec![4]);
        assert_eq!(
            Terminator::Br { cond: 0, then_: 1, else_: 2 }.successors(),
            vec![1, 2]
        );
        assert!(Terminator::Ret(None).successors().is_empty());
    }
}
