//! Criterion-less benchmark harness (no criterion in the offline vendor
//! set): warmup + timed iterations, median / MAD / min reporting, and
//! throughput helpers. Used by every target in `benches/`.

use std::time::Instant;

use crate::util::stats::{mad, median};

/// One measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall times in seconds.
    pub samples: Vec<f64>,
    /// Optional work units per iteration (for throughput lines).
    pub units: Option<(u64, &'static str)>,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        median(&self.samples)
    }

    pub fn report(&self) -> String {
        let med = self.median_s();
        let spread = mad(&self.samples);
        let min = self.samples.iter().cloned().fold(f64::MAX, f64::min);
        let mut line = format!(
            "{:<44} {:>12}  median {:>10}  mad {:>9}  min {:>10}",
            self.name,
            format!("{} iters", self.iters),
            fmt_time(med),
            fmt_time(spread),
            fmt_time(min),
        );
        if let Some((units, label)) = self.units {
            let rate = units as f64 / med;
            line.push_str(&format!("  {:>12}/s {}", fmt_count(rate), label));
        }
        line
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

fn fmt_count(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` samples.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    units: Option<(u64, &'static str)>,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let m = Measurement {
        name: name.to_string(),
        iters,
        samples,
        units,
    };
    println!("{}", m.report());
    m
}

/// Scale factor for bench workloads: `PISA_BENCH_SCALE` env (default 0.25 —
/// full-figure regeneration at paper-shape-preserving size in tens of
/// seconds; set 1.0 to reproduce EXPERIMENTS.md numbers exactly).
pub fn bench_scale() -> f64 {
    std::env::var("PISA_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_formats() {
        let m = bench("noop", 1, 5, Some((1000, "ops")), || {
            std::hint::black_box(42u64.wrapping_mul(7))
        });
        assert_eq!(m.iters, 5);
        assert!(m.median_s() < 0.1);
        assert!(m.report().contains("ops"));
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(2.5e-3), "2.500ms");
        assert_eq!(fmt_time(2.5e-6), "2.500us");
        assert_eq!(fmt_time(5e-9), "5.0ns");
        assert_eq!(fmt_count(3.2e6), "3.20M");
    }
}
