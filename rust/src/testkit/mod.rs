//! Mini property-testing framework (no proptest in the offline vendor set).
//!
//! Seeded generators + a runner that, on failure, retries with simple
//! input shrinking (halving sizes) and reports the failing seed so the case
//! is reproducible. Used by `rust/tests/prop_*.rs` for the coordinator
//! invariants the paper's pipeline depends on.

pub mod bench;

use crate::util::Rng;

/// Number of cases per property (kept modest: several properties run whole
/// interpreter executions per case).
pub const DEFAULT_CASES: u64 = 64;

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Run `prop` for `cases` seeds derived from `base_seed`. On failure, panic
/// with the seed and message — rerun with that seed to reproduce.
pub fn check_seeded(name: &str, base_seed: u64, cases: u64, prop: impl Fn(&mut Rng) -> CaseResult) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (seed {seed}, case {case}): {msg}");
        }
    }
}

/// `check_seeded` with defaults.
pub fn check(name: &str, prop: impl Fn(&mut Rng) -> CaseResult) {
    check_seeded(name, 0xDEFA017, DEFAULT_CASES, prop)
}

/// Assert helper producing `CaseResult`s.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Generators ------------------------------------------------------------

/// Uniform usize in [lo, hi].
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Vector of f64 in [lo, hi).
pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| rng.range_f64(lo, hi)).collect()
}

/// Generate a random structured program: nested counted loops (bounded trip
/// counts), arithmetic over a register pool, loads/stores into a shared
/// buffer with in-bounds random indexing, and the occasional if/else.
/// Same seed ⇒ same program; used by the `prop_*` integration tests
/// (pipeline invariants, chunked/per-event metric equivalence).
pub fn random_program(rng: &mut Rng) -> crate::ir::Program {
    use crate::ir::ProgramBuilder;
    let mut b = ProgramBuilder::new("rand");
    let len = 64usize;
    let data: Vec<f64> = (0..len).map(|_| rng.range_f64(0.5, 2.0)).collect();
    let buf = b.alloc_f64_init("buf", &data);
    let len_reg = b.const_i(len as i64);

    let mut pool: Vec<crate::ir::Reg> = (0..4).map(|i| b.const_f(1.0 + i as f64)).collect();
    let depth = usize_in(rng, 1, 3);
    gen_block(&mut b, rng, &mut pool, buf, len_reg, depth);
    let ret = pool[0];
    b.finish(Some(ret))
}

fn gen_block(
    b: &mut crate::ir::ProgramBuilder,
    rng: &mut Rng,
    pool: &mut Vec<crate::ir::Reg>,
    buf: crate::ir::BufRef,
    len_reg: crate::ir::Reg,
    depth: usize,
) {
    for _ in 0..usize_in(rng, 1, 5) {
        match rng.below(if depth > 0 { 5 } else { 3 }) {
            0 => {
                // arithmetic: fadd/fmul of two pool regs (stays finite:
                // magnitudes bounded by construction below)
                let x = pool[usize_in(rng, 0, pool.len() - 1)];
                let y = pool[usize_in(rng, 0, pool.len() - 1)];
                let z = if rng.below(2) == 0 { b.fadd(x, y) } else { b.fmul(x, y) };
                // clamp via fmin to keep values bounded across loops
                let cap = b.const_f(4.0);
                let z = b.fmin(z, cap);
                let slot = usize_in(rng, 0, pool.len() - 1);
                pool[slot] = z;
            }
            1 => {
                // load buf[idx % len]
                let idx_c = b.const_i(rng.below(64) as i64);
                let v = b.load_f64(buf, idx_c);
                let slot = usize_in(rng, 0, pool.len() - 1);
                pool[slot] = v;
            }
            2 => {
                // store pool reg to buf[idx]
                let idx_c = b.const_i(rng.below(64) as i64);
                let v = pool[usize_in(rng, 0, pool.len() - 1)];
                b.store_f64(buf, idx_c, v);
            }
            3 => {
                // bounded counted loop
                let trip = b.const_i(1 + rng.below(8) as i64);
                let mut inner_pool = pool.clone();
                // deterministic sub-rng so closure borrows don't fight
                let mut sub = Rng::new(rng.next_u64());
                b.counted_loop(trip, |b, i| {
                    let idx = b.rem(i, len_reg);
                    let v = b.load_f64(buf, idx);
                    inner_pool[0] = v;
                    gen_block(b, &mut sub, &mut inner_pool, buf, len_reg, depth - 1);
                });
            }
            _ => {
                // if/else on a data comparison
                let x = pool[usize_in(rng, 0, pool.len() - 1)];
                let y = pool[usize_in(rng, 0, pool.len() - 1)];
                let c = b.fcmp_lt(x, y);
                let mut sub1 = Rng::new(rng.next_u64());
                let mut sub2 = Rng::new(rng.next_u64());
                let mut p1 = pool.clone();
                let mut p2 = pool.clone();
                b.if_then_else(
                    c,
                    |b| gen_block(b, &mut sub1, &mut p1, buf, len_reg, 0),
                    |b| gen_block(b, &mut sub2, &mut p2, buf, len_reg, 0),
                );
            }
        }
    }
}

/// O(n·C) fully-associative LRU oracle over line ids: exact miss count for
/// a cache of `cap_lines` lines, as an explicit recency stack. The shared
/// cross-validation reference for the traffic subsystem's one-pass MRC
/// (`rust/src/traffic/mrc.rs` unit tests and `rust/tests/prop_traffic.rs`
/// both replay against this one implementation).
pub fn naive_lru_misses(lines: impl IntoIterator<Item = u64>, cap_lines: usize) -> u64 {
    let mut stack: Vec<u64> = Vec::new(); // most recent last
    let mut misses = 0u64;
    for line in lines {
        if let Some(pos) = stack.iter().position(|&l| l == line) {
            stack.remove(pos);
        } else {
            misses += 1;
            if stack.len() == cap_lines {
                stack.remove(0); // evict LRU
            }
        }
        stack.push(line);
    }
    misses
}

/// The traffic subsystem's **pre-hierarchy shadow bank**, kept as a
/// test-only oracle: three *independent* set-associative caches — each
/// seeing every access — at the same L1/L2/LLC shapes the hierarchy
/// replay uses ([`crate::traffic::HIERARCHY_LEVELS`]). Its DRAM figure
/// cannot subtract upper-level hits (an access absorbed by the L1-shaped
/// cache still refreshes and fills the LLC-shaped one), which is exactly
/// the accounting regression `rust/tests/prop_hierarchy.rs` proves the
/// hierarchy fixes: hierarchy DRAM bytes ≤ this bank's figure on every
/// suite kernel, strictly less where upper-level hits carry the traffic.
pub struct IndependentBank {
    caches: Vec<crate::sim::cache::Cache>,
}

impl Default for IndependentBank {
    fn default() -> Self {
        Self::new()
    }
}

impl IndependentBank {
    pub fn new() -> IndependentBank {
        let line = crate::traffic::MRC_LINE_BYTES as usize;
        IndependentBank {
            caches: crate::traffic::HIERARCHY_LEVELS
                .iter()
                .map(|c| {
                    crate::sim::cache::Cache::new(c.capacity_bytes as usize, c.ways as usize, line)
                })
                .collect(),
        }
    }

    /// Every cache sees every access (the old bank's defining property).
    pub fn access(&mut self, addr: u64, is_store: bool) {
        for c in &mut self.caches {
            c.access(addr, is_store);
        }
    }

    /// Per-cache (hits, misses, writebacks), L1 → LLC shapes.
    pub fn stats(&self) -> Vec<(u64, u64, u64)> {
        self.caches.iter().map(|c| (c.hits, c.misses, c.writebacks)).collect()
    }

    /// The DRAM bytes the old accounting reported: LLC-shaped fills +
    /// dirty evictions × 64 B, with the LLC-shaped cache fed (and its LRU
    /// refreshed) by every access including those the upper shapes absorb.
    pub fn dram_bytes(&self) -> u64 {
        let llc = self.caches.last().expect("bank has three caches");
        (llc.misses + llc.writebacks) * crate::traffic::MRC_LINE_BYTES
    }
}

/// Replay a captured `(addr, size, is_store)` stream through the old
/// independent bank and return its DRAM-byte figure.
pub fn independent_bank_dram_bytes(accs: &[(u64, u8, bool)]) -> u64 {
    let mut bank = IndependentBank::new();
    for &(addr, _, is_store) in accs {
        bank.access(addr, is_store);
    }
    bank.dram_bytes()
}

/// Vector of addresses: mixture of sequential runs and random jumps —
/// shaped like real traces (stresses reuse/entropy analyzers more than
/// uniform noise).
pub fn address_trace(rng: &mut Rng, len: usize, span: u64) -> Vec<u64> {
    let mut out = Vec::with_capacity(len);
    let mut cur = 0x1_0000u64;
    while out.len() < len {
        if rng.below(4) == 0 {
            cur = 0x1_0000 + rng.below(span) * 8;
        }
        let run = 1 + rng.below(16);
        for _ in 0..run {
            if out.len() >= len {
                break;
            }
            out.push(cur);
            cur += 8;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_tautology() {
        check("tautology", |rng| {
            let v = usize_in(rng, 1, 10);
            prop_assert!((1..=10).contains(&v), "range violated: {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_panics_with_seed_on_failure() {
        check("fails", |rng| {
            let v = usize_in(rng, 0, 100);
            prop_assert!(v < 95, "hit {v}");
            Ok(())
        });
    }

    #[test]
    fn address_trace_has_runs_and_jumps() {
        let mut rng = Rng::new(3);
        let t = address_trace(&mut rng, 1000, 1 << 20);
        assert_eq!(t.len(), 1000);
        let seq_pairs = t.windows(2).filter(|w| w[1] == w[0] + 8).count();
        assert!(seq_pairs > 300, "want sequential runs, got {seq_pairs}");
        let jumps = t.windows(2).filter(|w| w[1] != w[0] + 8).count();
        assert!(jumps > 20, "want jumps, got {jumps}");
    }
}
