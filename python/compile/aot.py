"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT lowered.compiler_ir("hlo") protos, NOT .serialize()) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (what the published `xla` 0.1.6 crate links)
rejects (`proto.id() <= INT_MAX`). The HLO text parser reassigns ids, so
text round-trips cleanly. See /opt/xla-example/load_hlo/.

Emitted artifacts (all fp32, shapes below are the runtime ABI):

  entropy.hlo.txt  (counts [G,B], weights [G,B]) -> (H [G], diff [])
  spatial.hlo.txt  (hist [L,D], binv [D])        -> (avg [L], scores [L-1])
  pca4.hlo.txt     (x [N,4], mask [N])           -> (scores [N,2], loadings
                                                     [4,2], eig [2], evr [2])
  pca8.hlo.txt     same with F=8
  model.hlo.txt    analysis_suite: all of the above fused in one module
  manifest.json    shape/ABI manifest consumed by rust/src/runtime

Usage: cd python && python -m compile.aot --out ../artifacts/model.hlo.txt
Python runs only here (and in pytest); never on the Rust analysis path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---- Fixed AOT shapes (the runtime ABI) -----------------------------------
G = 16     # max granularity rows (rust uses 11: shifts 0..10)
B = 4096   # count-of-counts slots per granularity
L = 8      # line sizes: 8B..1KB (2^3..2^10)
D = 64     # log2 reuse-distance bins per line size
N = 16     # max applications in one PCA batch (paper uses 12)
K = 2      # principal components
PCA_FEATURES = (4, 8)  # paper Fig 6 uses 4 features; 8 for extended analysis


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """name -> (hlo_text, input_shapes, output_shapes)."""
    arts = {}

    def add(name, fn, specs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        outs = jax.eval_shape(fn, *specs)
        leaves = jax.tree_util.tree_leaves(outs)
        arts[name] = (
            text,
            [list(s.shape) for s in specs],
            [list(o.shape) for o in leaves],
        )

    add("entropy", model.entropy_graph, [f32(G, B), f32(G, B)])
    add("spatial", model.spatial_graph, [f32(L, D), f32(D)])
    for f in PCA_FEATURES:
        add(f"pca{f}", lambda x, m: model.pca_graph(x, m, k=K), [f32(N, f), f32(N)])
    add(
        "model",
        model.analysis_suite,
        [f32(G, B), f32(G, B), f32(L, D), f32(D), f32(N, PCA_FEATURES[0]), f32(N)],
    )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the model.hlo.txt stamp; siblings written next to it")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)

    arts = lower_all()
    manifest = {
        "abi": 1,
        "shapes": {"G": G, "B": B, "L": L, "D": D, "N": N, "K": K,
                   "pca_features": list(PCA_FEATURES)},
        "artifacts": {},
    }
    for name, (text, ins, outs) in arts.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt", "inputs": ins, "outputs": outs,
        }
        print(f"wrote {path} ({len(text)} chars, in={ins} out={outs})")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
