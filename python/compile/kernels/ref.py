"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
checked against the function of the same name here (pytest + hypothesis sweeps
in python/tests/). Keep these boring and obviously-correct.

Math background (paper §II):

* Memory entropy: Shannon entropy over the distribution of accessed memory
  addresses, computed at several granularities g (address >> g). Given a
  bucket-count histogram ``counts[b]`` the entropy is
  ``H = -sum_b p_b * log2(p_b)`` with ``p_b = counts[b] / sum(counts)``.
  Empty buckets contribute 0.

* entropy_diff_mem (paper Fig 5): mean of consecutive differences of the
  per-granularity entropies, i.e. ``mean(H[g] - H[g+1])`` — the average
  entropy *drop* when doubling the access granularity. High values indicate
  the address stream loses randomness quickly with coarser lines (good for
  conventional caches → NOT an NMC candidate).

* Spatial locality (paper §II-A, after Gu et al.): from average data-temporal
  reuse (DTR) distances ``d[l]`` measured at line size ``2^l``, the score for
  doubling l→l+1 is the relative reduction ``(d[l] - d[l+1]) / d[l]``,
  clamped to [0, 1] (a growing DTR under larger lines means no spatial reuse).

* Covariance: ``C = Z^T Z / (n - 1)`` where Z is the column-standardized
  metric matrix. The Pallas kernel computes the raw ``X^T Y`` product tile;
  standardization and scaling live in the (traced-jnp) model layer.
"""

from __future__ import annotations

import jax.numpy as jnp


def entropy_ref(counts: jnp.ndarray) -> jnp.ndarray:
    """Shannon entropy (bits) per row of a [G, B] count matrix."""
    counts = counts.astype(jnp.float32)
    total = jnp.sum(counts, axis=-1, keepdims=True)
    p = jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 0.0)
    plogp = jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-38)), 0.0)
    return -jnp.sum(plogp, axis=-1)


def entropy_weighted_ref(counts: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Count-of-counts entropy: H = -sum w_b (c_b/T) log2(c_b/T), T = sum w·c.

    Equals entropy_ref on the expanded histogram where count value c_b is
    repeated w_b times; this identity is property-tested in the suite.
    """
    counts = counts.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    total = jnp.sum(counts * weights, axis=-1, keepdims=True)
    p = jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 0.0)
    plogp = jnp.where(p > 0, weights * p * jnp.log2(jnp.maximum(p, 1e-38)), 0.0)
    return -jnp.sum(plogp, axis=-1)


def entropy_diff_ref(entropies: jnp.ndarray) -> jnp.ndarray:
    """Paper Fig-5 metric: mean consecutive entropy drop across granularities.

    entropies: [..., G] with G >= 2, ordered fine→coarse.
    """
    d = entropies[..., :-1] - entropies[..., 1:]
    return jnp.mean(d, axis=-1)


def matmul_xt_y_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """X^T @ Y for X:[N,F], Y:[N,K] -> [F,K] in fp32."""
    return jnp.matmul(x.astype(jnp.float32).T, y.astype(jnp.float32))


def covariance_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Column-standardized covariance C = Z^T Z / (n-1) for X:[N,F]."""
    x = x.astype(jnp.float32)
    n = x.shape[0]
    mu = jnp.mean(x, axis=0, keepdims=True)
    sd = jnp.std(x, axis=0, keepdims=True)
    # near-constant columns standardize to exact zero (an epsilon divisor
    # would amplify fp32 mean-rounding noise by ~1e12)
    z = jnp.where(sd > 1e-6, (x - mu) / jnp.maximum(sd, 1e-6), 0.0)
    return jnp.matmul(z.T, z) / jnp.float32(max(n - 1, 1))


def spatial_score_ref(avg_dtr: jnp.ndarray) -> jnp.ndarray:
    """Spatial-locality scores from per-line-size mean DTR distances.

    avg_dtr: [..., L] mean reuse distances at line sizes 2^l (fine→coarse).
    Returns [..., L-1] scores in [0, 1]; score[l] ≈ 1 means doubling the line
    from 2^l to 2^(l+1) halves the reuse distance (perfect spatial reuse).
    """
    d0 = avg_dtr[..., :-1]
    d1 = avg_dtr[..., 1:]
    score = (d0 - d1) / jnp.maximum(d0, 1e-12)
    return jnp.clip(score, 0.0, 1.0)


def weighted_mean_hist_ref(hist: jnp.ndarray, bin_values: jnp.ndarray) -> jnp.ndarray:
    """Mean of a distribution given a histogram [L, D] and bin values [D]."""
    hist = hist.astype(jnp.float32)
    total = jnp.sum(hist, axis=-1)
    s = jnp.sum(hist * bin_values[None, :], axis=-1)
    return jnp.where(total > 0, s / jnp.maximum(total, 1.0), 0.0)


def pca_ref(x: jnp.ndarray, k: int = 2):
    """Dense PCA oracle via eigh on the standardized covariance.

    Returns (scores [N,k], loadings [F,k], explained_variance_ratio [k]).
    Signs are normalized so each loading column's max-|.| element is >= 0.
    """
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=0, keepdims=True)
    sd = jnp.std(x, axis=0, keepdims=True)
    z = jnp.where(sd > 1e-6, (x - mu) / jnp.maximum(sd, 1e-6), 0.0)
    c = jnp.matmul(z.T, z) / jnp.float32(max(x.shape[0] - 1, 1))
    w, v = jnp.linalg.eigh(c)  # ascending
    order = jnp.argsort(-w)
    w = w[order][:k]
    v = v[:, order][:, :k]
    # deterministic sign: flip columns whose max-abs entry is negative
    idx = jnp.argmax(jnp.abs(v), axis=0)
    signs = jnp.sign(v[idx, jnp.arange(k)])
    signs = jnp.where(signs == 0, 1.0, signs)
    v = v * signs[None, :]
    scores = jnp.matmul(z, v)
    evr = w / jnp.maximum(jnp.sum(jnp.maximum(w, 0.0)), 1e-12)
    return scores, v, evr
