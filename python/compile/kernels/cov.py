"""Pallas kernel: MXU-shaped X^T·Y tile matmul (covariance building block).

Input  : x [N, F], y [N, K]  (fp32)
Output : x^T @ y  [F, K]

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * The contraction runs over N — the grid's innermost axis — so each program
    multiplies a [BN, BF]ᵀ × [BN, BK] tile pair on the MXU and accumulates
    into a VMEM scratch [BF, BK]. This is the classic k-inner matmul schedule:
    output tile stays resident in VMEM, input tiles stream HBM→VMEM.
  * Block sizes default to (BN, BF, BK) = (128, 128, 128): MXU-native for
    fp32 (128×128 systolic array); the PCA problem here is tiny (N≈12, F≈8)
    so a single tile suffices, but the schedule scales to the large
    "many-windows × many-metrics" matrices the coordinator can batch.
  * On a real TPU the inputs would be bf16 with fp32 accumulation; inputs
    here are metric matrices of magnitude ~1–30 where fp32 is exact enough
    and keeps the oracle comparison tight.

The standardization (mean/std) and 1/(n-1) scaling that turn X^T X into a
covariance live in model.py as traced jnp — they are O(NF), not hot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_MXU = 128


def _xty_kernel(x_ref, y_ref, o_ref, acc_ref):
    """Accumulate x_tileᵀ @ y_tile over the contraction (innermost) grid axis."""
    kn = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kn == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [BN, BF]ᵀ × [BN, BK] → [BF, BK] on the MXU; fp32 accumulate.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kn == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_f", "block_k"))
def matmul_xt_y(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    block_n: int = _MXU,
    block_f: int = _MXU,
    block_k: int = _MXU,
) -> jnp.ndarray:
    """X^T @ Y via a Pallas tiled matmul. Shapes are zero-padded to blocks
    (zero rows contribute nothing to the contraction)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    n, f = x.shape
    n2, k = y.shape
    assert n == n2, f"contraction mismatch: {n} vs {n2}"
    npad = -(-n // block_n) * block_n
    fpad = -(-f // block_f) * block_f
    kpad = -(-k // block_k) * block_k
    xp = jnp.zeros((npad, fpad), jnp.float32).at[:n, :f].set(x)
    yp = jnp.zeros((npad, kpad), jnp.float32).at[:n, :k].set(y)

    grid = (fpad // block_f, kpad // block_k, npad // block_n)
    out = pl.pallas_call(
        _xty_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_f), lambda i, j, kn: (kn, i)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kn: (kn, j)),
        ],
        out_specs=pl.BlockSpec((block_f, block_k), lambda i, j, kn: (i, j)),
        out_shape=jax.ShapeDtypeStruct((fpad, kpad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_f, block_k), jnp.float32)],
        interpret=True,
    )(xp, yp)
    return out[:f, :k]


def covariance(x: jnp.ndarray, **blocks) -> jnp.ndarray:
    """Column-standardized covariance C = Z^T Z / (n-1), Z from the Pallas
    matmul. Matches ref.covariance_ref."""
    x = x.astype(jnp.float32)
    n = x.shape[0]
    mu = jnp.mean(x, axis=0, keepdims=True)
    sd = jnp.std(x, axis=0, keepdims=True)
    z = jnp.where(sd > 1e-6, (x - mu) / jnp.maximum(sd, 1e-6), 0.0)
    return matmul_xt_y(z, z, **blocks) / jnp.float32(max(n - 1, 1))
