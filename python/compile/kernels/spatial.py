"""Pallas kernel: spatial-locality scores from reuse-distance histograms.

Input  : hist [L, D]   — per-line-size log2-binned DTR histograms
         binv [1, D]   — representative distance value per bin
Output : avg  [L]      — mean reuse distance per line size
         (score [L-1] is derived from avg in traced jnp — O(L))

The Rust analyzers bin exact Olken reuse distances into D=64 log2 buckets per
line size l ∈ {8B … 1KB}; this kernel collapses each [1, D] row into its mean
distance, which spatial_score() turns into the paper's §II-A locality score
(relative DTR reduction when doubling the line).

TPU mapping: one grid row per (line-size block); the D axis fits one VMEM
block (D=64 ≤ 128 lanes → padded to 128). The kernel is a fused
weighted-sum + count-sum over the lane axis, i.e. two VPU reductions per row
in a single pass — memory-bound, one HBM read of the histogram.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SUBLANE = 8
_LANE = 128


def _wmean_kernel(hist_ref, binv_ref, out_ref):
    hist = hist_ref[...].astype(jnp.float32)  # [BL, D]
    binv = binv_ref[...].astype(jnp.float32)  # [1, D]
    total = jnp.sum(hist, axis=1, keepdims=True)
    s = jnp.sum(hist * binv, axis=1, keepdims=True)
    out_ref[...] = jnp.where(total > 0, s / jnp.maximum(total, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("block_l",))
def weighted_mean_hist(hist: jnp.ndarray, bin_values: jnp.ndarray, *, block_l: int = _SUBLANE) -> jnp.ndarray:
    """Mean of the binned distribution per row: hist [L, D], bin_values [D] → [L]."""
    hist = hist.astype(jnp.float32)
    l, d = hist.shape
    lp = -(-l // block_l) * block_l
    dp = -(-d // _LANE) * _LANE
    hp = jnp.zeros((lp, dp), jnp.float32).at[:l, :d].set(hist)
    bp = jnp.zeros((1, dp), jnp.float32).at[0, :d].set(bin_values.astype(jnp.float32))

    out = pl.pallas_call(
        _wmean_kernel,
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lp, 1), jnp.float32),
        interpret=True,
    )(hp, bp)
    return out[:l, 0]


def spatial_score(avg_dtr: jnp.ndarray) -> jnp.ndarray:
    """Paper §II-A spatial-locality score: relative DTR reduction per line-size
    doubling, clamped to [0, 1]. avg_dtr [..., L] fine→coarse → [..., L-1]."""
    d0 = avg_dtr[..., :-1]
    d1 = avg_dtr[..., 1:]
    return jnp.clip((d0 - d1) / jnp.maximum(d0, 1e-12), 0.0, 1.0)


def spatial_from_hist(hist: jnp.ndarray, bin_values: jnp.ndarray) -> jnp.ndarray:
    """Fused: histograms [L, D] → locality scores [L-1]."""
    return spatial_score(weighted_mean_hist(hist, bin_values))
