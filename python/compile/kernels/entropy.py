"""Pallas kernel: batched weighted Shannon-entropy reduction.

The Rust analyzers count exact per-address occurrences (HashMap) and compress
the count *multiset* into a fixed-shape count-of-counts form: for each
distinct count value c with multiplicity w, one slot (c, w). Entropy only
depends on the count multiset:

    H = -sum_b  w_b * (c_b / T) * log2(c_b / T),    T = sum_b w_b * c_b

so a trace with millions of unique addresses reduces EXACTLY to a few
thousand (c, w) slots — that is what makes an AOT'd fixed-shape [G, B] kernel
able to compute exact memory entropy (paper §II-A). Plain histograms are the
w == 1 special case.

Input  : counts  [G, B]  — per-granularity distinct count values (0 = empty)
         weights [G, B]  — multiplicity of each count value
Output : H       [G]     — Shannon entropy in bits per granularity row

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid = (G / BG, B / BB): each program owns a [BG, BB] tile in VMEM; the
    bucket axis streams block-by-block (HBM→VMEM schedule in the BlockSpec
    index_map), the granularity axis is tiled across sublanes.
  * A VMEM scratch accumulator [BG, 1] carries partial -w·p·log2(p) sums
    across the bucket-block loop; totals are precomputed (one jnp reduction)
    so the kernel is single-pass and the accumulator never leaves VMEM.
  * BB is a multiple of 128 lanes, BG a multiple of 8 sublanes — exactly the
    fp32 native VMEM tile, so the reduction vectorizes fully on the VPU.

interpret=True everywhere in this repo: the CPU PJRT plugin cannot execute
Mosaic custom-calls; correctness is validated against ref.entropy_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# fp32 native tile on TPU is (8, 128); keep blocks multiples of that.
_SUBLANE = 8
_LANE = 128
_LOG2E = 1.4426950408889634


def _entropy_kernel(total_ref, counts_ref, weights_ref, out_ref, acc_ref):
    """One [BG, BB] tile: accumulate -w*p*log2(p) into acc, flush on last block."""
    bj = pl.program_id(1)
    nbj = pl.num_programs(1)

    @pl.when(bj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    counts = counts_ref[...].astype(jnp.float32)  # [BG, BB]
    weights = weights_ref[...].astype(jnp.float32)  # [BG, BB]
    total = total_ref[...].astype(jnp.float32)  # [BG, 1] row totals (>=0)
    p = counts / jnp.maximum(total, 1.0)
    # w * p * log2(p) with the 0*log(0)=0 convention; max() keeps log finite.
    plogp = jnp.where(p > 0, weights * p * (jnp.log(jnp.maximum(p, 1e-38)) * _LOG2E), 0.0)
    acc_ref[...] += -jnp.sum(plogp, axis=1, keepdims=True)

    @pl.when(bj == nbj - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_g", "block_b"))
def entropy_weighted(
    counts: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    block_g: int = _SUBLANE,
    block_b: int = 4 * _LANE,
) -> jnp.ndarray:
    """Weighted Shannon entropy (bits) per row: counts/weights [G, B] → [G].

    Rows may be all-zero (entropy 0). G and B are padded up to block
    multiples; padding slots have weight 0 so they contribute nothing.
    """
    counts = counts.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    g, b = counts.shape
    gp = -(-g // block_g) * block_g
    bp = -(-b // block_b) * block_b
    cp = jnp.zeros((gp, bp), jnp.float32).at[:g, :b].set(counts)
    wp = jnp.zeros((gp, bp), jnp.float32).at[:g, :b].set(weights)
    totals = jnp.sum(cp * wp, axis=1, keepdims=True)  # [gp, 1]

    grid = (gp // block_g, bp // block_b)
    out = pl.pallas_call(
        _entropy_kernel,
        grid=grid,
        in_specs=[
            # row totals: broadcast along the bucket-block axis
            pl.BlockSpec((block_g, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_g, block_b), lambda i, j: (i, j)),
            pl.BlockSpec((block_g, block_b), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_g, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_g, 1), jnp.float32)],
        interpret=True,
    )(totals, cp, wp)
    return out[:g, 0]


def entropy(counts: jnp.ndarray, **blocks) -> jnp.ndarray:
    """Plain-histogram entropy: the weights == 1 special case."""
    return entropy_weighted(counts, jnp.ones_like(counts, dtype=jnp.float32), **blocks)


def entropy_diff(entropies: jnp.ndarray) -> jnp.ndarray:
    """Paper Fig-5 derived metric: mean consecutive entropy drop (traced-jnp;
    the heavy part is the histogram reduction above, this is O(G))."""
    d = entropies[..., :-1] - entropies[..., 1:]
    return jnp.mean(d, axis=-1)
