"""L2: the PISA-NMC analytics compute graphs (JAX, calling the Pallas kernels).

Three graphs, AOT-lowered by aot.py to HLO text and executed from the Rust
coordinator through PJRT (python never runs at analysis time):

  entropy_graph   counts/weights [G, B]        -> (H [G], entropy_diff scalar)
                  exact memory entropy per granularity from count-of-counts
                  (paper Fig 3a) + the Fig-5 derived metric.

  spatial_graph   hist [L, D], binv [D]        -> (avg_dtr [L], scores [L-1])
                  mean reuse distance per line size and the SSII-A spatial-
                  locality score per line-size doubling (paper Fig 3b).

  pca_graph       x [N, F], mask [N]           -> (scores [N, K], loadings
                  [F, K], eigenvalues [K], explained_variance_ratio [K])
                  masked standardization -> Pallas covariance -> power
                  iteration with Hotelling deflation (paper Fig 6).

Shapes are fixed at AOT time (see aot.py SHAPES); the Rust side pads with
mask/weight zeros. All graphs are pure fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import cov as cov_k
from compile.kernels import entropy as entropy_k
from compile.kernels import spatial as spatial_k

# Power-iteration budget. The covariance matrices here are tiny (F <= 8) and
# well-separated after standardization; 96 iterations converges far below
# fp32 resolution and keeps the unrolled HLO compact.
POWER_ITERS = 96


def entropy_graph(counts: jnp.ndarray, weights: jnp.ndarray):
    """[G, B] count-of-counts -> per-granularity entropy + Fig-5 diff metric."""
    h = entropy_k.entropy_weighted(counts, weights)
    return h, entropy_k.entropy_diff(h)


def spatial_graph(hist: jnp.ndarray, bin_values: jnp.ndarray):
    """[L, D] DTR histograms -> mean DTR per line size + locality scores."""
    avg = spatial_k.weighted_mean_hist(hist, bin_values)
    return avg, spatial_k.spatial_score(avg)


def _masked_standardize(x: jnp.ndarray, mask: jnp.ndarray):
    """Standardize columns over the masked (valid) rows only; padded rows
    come out as exact zeros so they vanish from the covariance."""
    m = mask.astype(jnp.float32)[:, None]  # [N, 1]
    n_eff = jnp.maximum(jnp.sum(m), 1.0)
    mu = jnp.sum(x * m, axis=0, keepdims=True) / n_eff
    var = jnp.sum(((x - mu) ** 2) * m, axis=0, keepdims=True) / n_eff
    sd = jnp.sqrt(var)
    # near-constant columns standardize to exact zero (see kernels/ref.py)
    z = jnp.where(sd > 1e-6, (x - mu) / jnp.maximum(sd, 1e-6), 0.0) * m
    return z, n_eff


def _power_iteration(c: jnp.ndarray, k: int):
    """Top-k eigenpairs of symmetric PSD c via power iteration + deflation.

    Deterministic start vectors (basis-aligned with a small full-ones tilt so
    a start orthogonal to the eigenvector cannot occur for these matrices).
    """
    f = c.shape[0]
    eigvals = []
    eigvecs = []
    for j in range(k):
        v0 = jnp.ones((f,), jnp.float32) + 2.0 * jax.nn.one_hot(j, f, dtype=jnp.float32)
        v0 = v0 / jnp.linalg.norm(v0)

        def body(_, v, c=c):
            w = c @ v
            return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

        v = jax.lax.fori_loop(0, POWER_ITERS, body, v0)
        lam = v @ (c @ v)
        eigvals.append(lam)
        eigvecs.append(v)
        c = c - lam * jnp.outer(v, v)  # Hotelling deflation
    return jnp.stack(eigvals), jnp.stack(eigvecs, axis=1)  # [K], [F, K]


def pca_graph(x: jnp.ndarray, mask: jnp.ndarray, k: int = 2):
    """Masked PCA: standardize -> Pallas covariance -> power iteration.

    Sign convention matches ref.pca_ref: each loading column is flipped so
    its max-|.| element is positive (stable across eigensolvers).
    """
    x = x.astype(jnp.float32)
    z, n_eff = _masked_standardize(x, mask)
    c = cov_k.matmul_xt_y(z, z) / jnp.maximum(n_eff - 1.0, 1.0)
    eigvals, v = _power_iteration(c, k)

    idx = jnp.argmax(jnp.abs(v), axis=0)
    signs = jnp.sign(v[idx, jnp.arange(k)])
    signs = jnp.where(signs == 0, 1.0, signs)
    v = v * signs[None, :]

    scores = z @ v  # [N, K]; padded rows are zero rows
    pos = jnp.maximum(eigvals, 0.0)
    evr = pos / jnp.maximum(jnp.sum(pos), 1e-12)
    return scores, v, eigvals, evr


def analysis_suite(counts, weights, hist, bin_values, x, mask):
    """Combined one-call module: everything the coordinator needs per run.

    Returned flat tuple order is the runtime ABI -- keep in sync with
    rust/src/runtime/artifacts.rs and aot.py's manifest.
    """
    h, hdiff = entropy_graph(counts, weights)
    avg, scores_sp = spatial_graph(hist, bin_values)
    scores_pca, loadings, eigvals, evr = pca_graph(x, mask)
    return h, hdiff, avg, scores_sp, scores_pca, loadings, eigvals, evr
