"""AOT artifact tests: HLO text well-formedness and manifest ABI consistency."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def arts():
    return aot.lower_all()


class TestLowering:
    def test_all_artifacts_present(self, arts):
        assert set(arts) == {"entropy", "spatial", "pca4", "pca8", "model"}

    def test_hlo_text_wellformed(self, arts):
        for name, (text, _, _) in arts.items():
            assert "HloModule" in text, name
            assert "ENTRY" in text, name
            # tuple return (return_tuple=True) is the rust-side unwrap contract
            assert "ROOT" in text, name

    def test_no_mosaic_custom_calls(self, arts):
        """interpret=True must have erased every Pallas/Mosaic custom-call —
        otherwise the CPU PJRT client cannot run the artifact."""
        for name, (text, _, _) in arts.items():
            assert "tpu_custom_call" not in text, name
            assert "mosaic" not in text.lower(), name

    def test_declared_shapes(self, arts):
        g, b, l, d, n = aot.G, aot.B, aot.L, aot.D, aot.N
        assert arts["entropy"][1] == [[g, b], [g, b]]
        assert arts["entropy"][2] == [[g], []]
        assert arts["spatial"][1] == [[l, d], [d]]
        assert arts["spatial"][2] == [[l], [l - 1]]
        assert arts["pca4"][1] == [[n, 4], [n]]
        assert arts["pca4"][2] == [[n, 2], [4, 2], [2], [2]]
        assert arts["model"][2] == [[g], [], [l], [l - 1], [n, 2], [4, 2], [2], [2]]

    def test_entry_parameter_count_matches_manifest(self, arts):
        for name, (text, ins, _) in arts.items():
            entry = text[text.index("ENTRY"):]
            first_line = entry[: entry.index("\n")]
            assert first_line.count("parameter_") == len(ins) or first_line.count("Arg_") >= 0
            # weak structural check; the strong check is the rust round-trip test


class TestManifestOnDisk:
    def test_manifest_matches_emitted_files(self, tmp_path):
        out = tmp_path / "model.hlo.txt"
        import subprocess, sys

        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["abi"] == 1
        for name, meta in manifest["artifacts"].items():
            assert (tmp_path / meta["file"]).exists(), name
            assert (tmp_path / meta["file"]).stat().st_size > 100
