"""Pallas spatial-locality kernel vs the pure-jnp oracle."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref
from compile.kernels.spatial import spatial_from_hist, spatial_score, weighted_mean_hist

hypothesis.settings.register_profile(
    "pallas", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("pallas")


def _bins(d=64):
    return jnp.asarray((2.0 ** np.arange(d)).astype(np.float32))


class TestWeightedMean:
    def test_point_mass(self):
        h = jnp.zeros((1, 64), jnp.float32).at[0, 5].set(10.0)
        np.testing.assert_allclose(np.asarray(weighted_mean_hist(h, _bins())), [32.0], rtol=1e-6)

    def test_empty_row_is_zero(self):
        h = jnp.zeros((3, 64), jnp.float32).at[1, 0].set(4.0)
        out = np.asarray(weighted_mean_hist(h, _bins()))
        assert out[0] == 0.0 and out[2] == 0.0 and out[1] == 1.0

    def test_matches_ref_random(self):
        h = jnp.asarray(np.random.default_rng(0).integers(0, 30, (8, 64)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(weighted_mean_hist(h, _bins())),
            np.asarray(ref.weighted_mean_hist_ref(h, _bins())),
            rtol=1e-5,
        )

    @hypothesis.given(l=st.integers(1, 12), d=st.integers(2, 128), seed=st.integers(0, 10_000))
    def test_matches_ref_any_shape(self, l, d, seed):
        h = jnp.asarray(np.random.default_rng(seed).integers(0, 20, (l, d)).astype(np.float32))
        bv = jnp.asarray(np.random.default_rng(seed + 1).uniform(0, 100, d).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(weighted_mean_hist(h, bv)),
            np.asarray(ref.weighted_mean_hist_ref(h, bv)),
            rtol=1e-4, atol=1e-4,
        )


class TestSpatialScore:
    def test_perfect_halving_is_half(self):
        """DTR halving per line-size doubling → score 0.5 everywhere."""
        avg = jnp.asarray([64.0, 32.0, 16.0, 8.0])
        np.testing.assert_allclose(np.asarray(spatial_score(avg)), [0.5, 0.5, 0.5], rtol=1e-6)

    def test_no_reduction_is_zero(self):
        avg = jnp.asarray([10.0, 10.0, 10.0])
        np.testing.assert_allclose(np.asarray(spatial_score(avg)), [0.0, 0.0], atol=1e-6)

    def test_growth_clamped_to_zero(self):
        avg = jnp.asarray([10.0, 20.0])
        np.testing.assert_allclose(np.asarray(spatial_score(avg)), [0.0], atol=1e-6)

    def test_matches_ref(self):
        avg = jnp.asarray(np.random.default_rng(2).uniform(1, 1e6, 8).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(spatial_score(avg)), np.asarray(ref.spatial_score_ref(avg)), rtol=1e-5
        )

    @hypothesis.given(seed=st.integers(0, 2**31 - 1))
    def test_scores_in_unit_interval(self, seed):
        avg = jnp.asarray(np.random.default_rng(seed).uniform(0, 1e7, 8).astype(np.float32))
        s = np.asarray(spatial_score(avg))
        assert (s >= 0.0).all() and (s <= 1.0).all()


class TestFused:
    def test_spatial_from_hist_pipeline(self):
        h = jnp.asarray(np.random.default_rng(3).integers(0, 40, (8, 64)).astype(np.float32))
        got = np.asarray(spatial_from_hist(h, _bins()))
        want = np.asarray(
            ref.spatial_score_ref(ref.weighted_mean_hist_ref(h, _bins()))
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
