"""Pallas entropy kernel vs the pure-jnp oracle (the core L1 signal)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.entropy import entropy, entropy_diff, entropy_weighted

hypothesis.settings.register_profile(
    "pallas", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("pallas")


def _rng(seed=0):
    return np.random.default_rng(seed)


class TestEntropyBasics:
    def test_uniform_histogram_is_log2_b(self):
        c = jnp.ones((1, 256), jnp.float32)
        h = entropy(c)
        np.testing.assert_allclose(np.asarray(h), [8.0], rtol=1e-5)

    def test_single_hot_bucket_is_zero(self):
        c = jnp.zeros((1, 128), jnp.float32).at[0, 17].set(1000.0)
        np.testing.assert_allclose(np.asarray(entropy(c)), [0.0], atol=1e-6)

    def test_all_zero_row_is_zero(self):
        c = jnp.zeros((3, 128), jnp.float32).at[1, :].set(1.0)
        h = np.asarray(entropy(c))
        assert h[0] == 0.0 and h[2] == 0.0
        np.testing.assert_allclose(h[1], 7.0, rtol=1e-5)

    def test_matches_ref_random(self):
        c = jnp.asarray(_rng(3).integers(0, 1000, (11, 700)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(entropy(c)), np.asarray(ref.entropy_ref(c)), rtol=1e-4, atol=1e-5
        )

    def test_two_equal_buckets_one_bit(self):
        c = jnp.zeros((1, 128), jnp.float32).at[0, 0].set(5.0).at[0, 99].set(5.0)
        np.testing.assert_allclose(np.asarray(entropy(c)), [1.0], rtol=1e-6)


class TestEntropyWeighted:
    def test_weighted_equals_expanded(self):
        """Count-of-counts identity: (c, w) == histogram with c repeated w times."""
        rng = _rng(7)
        counts = rng.integers(1, 20, 40).astype(np.float32)
        weights = rng.integers(1, 6, 40).astype(np.float32)
        expanded = np.concatenate([np.full(int(w), c) for c, w in zip(counts, weights)])
        h_w = entropy_weighted(jnp.asarray(counts[None]), jnp.asarray(weights[None]))
        h_e = ref.entropy_ref(jnp.asarray(expanded[None]))
        np.testing.assert_allclose(np.asarray(h_w), np.asarray(h_e), rtol=1e-4)

    def test_weighted_matches_weighted_ref(self):
        rng = _rng(11)
        c = jnp.asarray(rng.integers(0, 500, (5, 300)).astype(np.float32))
        w = jnp.asarray(rng.integers(0, 8, (5, 300)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(entropy_weighted(c, w)),
            np.asarray(ref.entropy_weighted_ref(c, w)),
            rtol=1e-4,
            atol=1e-5,
        )

    def test_zero_weight_slots_ignored(self):
        c = jnp.asarray([[4.0, 999.0, 4.0]])
        w = jnp.asarray([[1.0, 0.0, 1.0]])
        np.testing.assert_allclose(np.asarray(entropy_weighted(c, w)), [1.0], rtol=1e-5)


class TestEntropyHypothesis:
    @hypothesis.given(
        g=st.integers(1, 17),
        b=st.integers(1, 600),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_any_shape(self, g, b, seed):
        c = jnp.asarray(_rng(seed).integers(0, 100, (g, b)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(entropy(c)), np.asarray(ref.entropy_ref(c)), rtol=1e-4, atol=1e-4
        )

    @hypothesis.given(
        block_g=st.sampled_from([8, 16]),
        block_b=st.sampled_from([128, 256, 512]),
        seed=st.integers(0, 1000),
    )
    def test_block_shape_invariance(self, block_g, block_b, seed):
        """Entropy must not depend on the VMEM tiling."""
        c = jnp.asarray(_rng(seed).integers(0, 50, (11, 777)).astype(np.float32))
        h = entropy(c, block_g=block_g, block_b=block_b)
        np.testing.assert_allclose(
            np.asarray(h), np.asarray(ref.entropy_ref(c)), rtol=1e-4, atol=1e-4
        )

    @hypothesis.given(seed=st.integers(0, 2**31 - 1))
    def test_entropy_bounded_by_log2_support(self, seed):
        c = jnp.asarray(_rng(seed).integers(0, 10, (4, 256)).astype(np.float32))
        h = np.asarray(entropy(c))
        support = np.asarray((c > 0).sum(axis=1))
        bound = np.log2(np.maximum(support, 1))
        assert (h <= bound + 1e-3).all()
        assert (h >= -1e-4).all()


class TestEntropyDiff:
    def test_fig5_metric(self):
        h = jnp.asarray([10.0, 9.0, 7.0, 7.0])
        np.testing.assert_allclose(np.asarray(entropy_diff(h)), 1.0, rtol=1e-6)

    def test_matches_ref(self):
        h = jnp.asarray(_rng(5).uniform(0, 20, (11,)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(entropy_diff(h)), np.asarray(ref.entropy_diff_ref(h)), rtol=1e-5
        )

    def test_constant_entropy_zero_diff(self):
        h = jnp.full((6,), 4.25, jnp.float32)
        np.testing.assert_allclose(np.asarray(entropy_diff(h)), 0.0, atol=1e-6)
