"""Pallas X^T·Y / covariance kernel vs the pure-jnp oracle."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref
from compile.kernels.cov import covariance, matmul_xt_y

hypothesis.settings.register_profile(
    "pallas", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("pallas")


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestMatmulXtY:
    def test_identity_contraction(self):
        x = jnp.eye(4, dtype=jnp.float32)
        y = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)
        np.testing.assert_allclose(np.asarray(matmul_xt_y(x, y)), np.asarray(y), rtol=1e-6)

    def test_matches_ref_small(self):
        x, y = _rand((12, 4), 0), _rand((12, 3), 1)
        np.testing.assert_allclose(
            np.asarray(matmul_xt_y(x, y)), np.asarray(ref.matmul_xt_y_ref(x, y)),
            rtol=1e-4, atol=1e-4,
        )

    def test_matches_ref_multi_tile(self):
        """Shapes that exceed one 128-block on every axis — exercises the
        contraction-axis accumulator across grid steps."""
        x, y = _rand((300, 150), 2), _rand((300, 200), 3)
        np.testing.assert_allclose(
            np.asarray(matmul_xt_y(x, y, block_n=128, block_f=128, block_k=128)),
            np.asarray(ref.matmul_xt_y_ref(x, y)),
            rtol=1e-3, atol=1e-2,
        )

    @hypothesis.given(
        n=st.integers(1, 200), f=st.integers(1, 40), k=st.integers(1, 40),
        seed=st.integers(0, 10_000),
    )
    def test_matches_ref_any_shape(self, n, f, k, seed):
        x, y = _rand((n, f), seed), _rand((n, k), seed + 1)
        np.testing.assert_allclose(
            np.asarray(matmul_xt_y(x, y)), np.asarray(ref.matmul_xt_y_ref(x, y)),
            rtol=1e-3, atol=1e-3,
        )

    @hypothesis.given(bn=st.sampled_from([128, 256]), seed=st.integers(0, 100))
    def test_block_shape_invariance(self, bn, seed):
        x, y = _rand((137, 9), seed), _rand((137, 5), seed + 7)
        np.testing.assert_allclose(
            np.asarray(matmul_xt_y(x, y, block_n=bn)),
            np.asarray(ref.matmul_xt_y_ref(x, y)),
            rtol=1e-3, atol=1e-3,
        )


class TestCovariance:
    def test_matches_ref(self):
        x = _rand((12, 8), 4)
        np.testing.assert_allclose(
            np.asarray(covariance(x)), np.asarray(ref.covariance_ref(x)),
            rtol=1e-4, atol=1e-4,
        )

    def test_diagonal_is_n_over_n_minus_1(self):
        """Standardized columns have population variance 1, so the sample-
        normalized diagonal is n/(n-1)."""
        x = _rand((40, 5), 5)
        c = np.asarray(covariance(x))
        np.testing.assert_allclose(np.diag(c), np.full(5, 40.0 / 39.0), rtol=1e-4)

    def test_symmetric_psd(self):
        x = _rand((30, 6), 6)
        c = np.asarray(covariance(x))
        np.testing.assert_allclose(c, c.T, atol=1e-4)
        w = np.linalg.eigvalsh(c)
        assert (w > -1e-3).all()

    def test_constant_column_zero_cov(self):
        x = np.array(_rand((20, 3), 7), copy=True)
        x[:, 1] = 3.14
        c = np.asarray(covariance(jnp.asarray(x)))
        np.testing.assert_allclose(c[1, :], 0.0, atol=1e-4)
        np.testing.assert_allclose(c[:, 1], 0.0, atol=1e-4)

    @hypothesis.given(n=st.integers(2, 64), f=st.integers(1, 12), seed=st.integers(0, 10_000))
    def test_matches_ref_any_shape(self, n, f, seed):
        x = _rand((n, f), seed)
        np.testing.assert_allclose(
            np.asarray(covariance(x)), np.asarray(ref.covariance_ref(x)),
            rtol=1e-3, atol=1e-3,
        )
