"""L2 graph tests: PCA vs dense eigh oracle, masking semantics, suite ABI."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "pallas", deadline=None, max_examples=15, derandomize=True
)
hypothesis.settings.load_profile("pallas")


def _metrics_matrix(n, f, seed):
    """Synthetic metric matrices shaped like the real feature tables:
    positive, different column scales, correlated columns."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(n, 2))
    mix = rng.normal(size=(2, f))
    x = base @ mix + 0.3 * rng.normal(size=(n, f)) + 5.0
    return jnp.asarray(x.astype(np.float32))


class TestPcaGraph:
    def test_matches_eigh_oracle(self):
        x = _metrics_matrix(12, 4, 0)
        mask = jnp.ones((12,), jnp.float32)
        scores, load, eig, evr = model.pca_graph(x, mask)
        scores_r, load_r, evr_r = ref.pca_ref(x)
        np.testing.assert_allclose(np.asarray(load), np.asarray(load_r), rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(np.asarray(scores), np.asarray(scores_r), rtol=5e-3, atol=1e-2)
        np.testing.assert_allclose(np.asarray(evr), np.asarray(evr_r), rtol=5e-3, atol=5e-3)

    def test_padding_rows_inert(self):
        """Appending masked-off rows must not change the valid-row results."""
        x12 = _metrics_matrix(12, 4, 1)
        m12 = jnp.ones((12,), jnp.float32)
        s12, l12, e12, _ = model.pca_graph(x12, m12)

        x16 = jnp.concatenate([x12, jnp.full((4, 4), 1e3, jnp.float32)], axis=0)
        m16 = jnp.concatenate([m12, jnp.zeros((4,), jnp.float32)])
        s16, l16, e16, _ = model.pca_graph(x16, m16)

        np.testing.assert_allclose(np.asarray(l16), np.asarray(l12), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s16[:12]), np.asarray(s12), rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s16[12:]), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(e16), np.asarray(e12), rtol=1e-4)

    def test_loadings_orthonormal(self):
        x = _metrics_matrix(14, 6, 2)
        _, load, _, _ = model.pca_graph(x, jnp.ones((14,), jnp.float32))
        g = np.asarray(load).T @ np.asarray(load)
        np.testing.assert_allclose(g, np.eye(2), atol=5e-3)

    def test_eigenvalues_descending_nonnegative(self):
        x = _metrics_matrix(12, 4, 3)
        _, _, eig, evr = model.pca_graph(x, jnp.ones((12,), jnp.float32))
        eig = np.asarray(eig)
        assert eig[0] >= eig[1] >= -1e-4
        assert abs(np.asarray(evr).sum() - 1.0) < 1e-3 or np.asarray(evr).sum() <= 1.0

    def test_two_clusters_separate_on_pc1(self):
        """Quadrant semantics used for Fig 6: well-separated app clusters get
        opposite-sign PC1 scores."""
        a = np.tile([1.0, 1.0, 10.0, 10.0], (6, 1))
        b = np.tile([10.0, 10.0, 1.0, 1.0], (6, 1))
        x = jnp.asarray(np.concatenate([a, b]) + 0.01 * np.random.default_rng(4).normal(size=(12, 4)))
        scores, _, _, _ = model.pca_graph(x.astype(jnp.float32), jnp.ones((12,), jnp.float32))
        pc1 = np.asarray(scores)[:, 0]
        assert (np.sign(pc1[:6]) == np.sign(pc1[0])).all()
        assert (np.sign(pc1[6:]) == -np.sign(pc1[0])).all()

    @hypothesis.given(seed=st.integers(0, 5000), f=st.sampled_from([4, 8]))
    def test_matches_oracle_random(self, seed, f):
        x = _metrics_matrix(12, f, seed)
        scores, load, eig, _ = model.pca_graph(x, jnp.ones((12,), jnp.float32))
        _, load_r, _ = ref.pca_ref(x)
        # Compare the spanned subspace (eigvec pairs can swap when nearly
        # degenerate): projection matrices must match.
        p = np.asarray(load) @ np.asarray(load).T
        pr = np.asarray(load_r) @ np.asarray(load_r).T
        gap = np.abs(np.asarray(eig)[0] - np.asarray(eig)[1])
        if gap > 1e-2:  # well-separated → subspace comparison is stable
            np.testing.assert_allclose(p, pr, atol=2e-2)


class TestEntropyGraph:
    def test_matches_refs(self):
        rng = np.random.default_rng(5)
        c = jnp.asarray(rng.integers(0, 100, (11, 500)).astype(np.float32))
        w = jnp.asarray(rng.integers(1, 5, (11, 500)).astype(np.float32))
        h, d = model.entropy_graph(c, w)
        hr = ref.entropy_weighted_ref(c, w)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(d), np.asarray(ref.entropy_diff_ref(hr)), rtol=1e-3, atol=1e-4)


class TestSpatialGraph:
    def test_matches_refs(self):
        rng = np.random.default_rng(6)
        h = jnp.asarray(rng.integers(0, 30, (8, 64)).astype(np.float32))
        bv = jnp.asarray((2.0 ** np.arange(64)).astype(np.float32))
        avg, sc = model.spatial_graph(h, bv)
        avg_r = ref.weighted_mean_hist_ref(h, bv)
        np.testing.assert_allclose(np.asarray(avg), np.asarray(avg_r), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(sc), np.asarray(ref.spatial_score_ref(avg_r)), rtol=1e-4, atol=1e-5)


class TestAnalysisSuite:
    def test_suite_equals_parts(self):
        """The fused model.hlo.txt module must return exactly the per-graph
        results, in the documented ABI order."""
        rng = np.random.default_rng(7)
        c = jnp.asarray(rng.integers(0, 50, (16, 256)).astype(np.float32))
        w = jnp.asarray(rng.integers(1, 4, (16, 256)).astype(np.float32))
        hist = jnp.asarray(rng.integers(0, 20, (8, 64)).astype(np.float32))
        bv = jnp.asarray((2.0 ** np.arange(64)).astype(np.float32))
        x = _metrics_matrix(16, 4, 8)
        mask = jnp.concatenate([jnp.ones((12,)), jnp.zeros((4,))]).astype(jnp.float32)

        out = model.analysis_suite(c, w, hist, bv, x, mask)
        h, hd = model.entropy_graph(c, w)
        avg, sc = model.spatial_graph(hist, bv)
        ps, pl_, pe, pevr = model.pca_graph(x, mask)
        for got, want in zip(out, (h, hd, avg, sc, ps, pl_, pe, pevr)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
