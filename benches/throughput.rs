//! Suite-level profiler throughput: the wall-clock number the chunked
//! event pipeline is accountable to. Runs `run_suite` at the default bench
//! scale (override with `PISA_BENCH_SCALE`), reports total trace events
//! per second of end-to-end suite time plus each app's own profiling rate
//! from `ExecStats`, then re-runs every kernel through the per-event
//! reference path for the before/after dispatch comparison.
//!
//! ```bash
//! cargo bench --bench throughput            # scale 0.25
//! PISA_BENCH_SCALE=1.0 cargo bench --bench throughput
//! ```

use std::time::Instant;

use pisa_nmc::analysis::{profile, profile_per_event};
use pisa_nmc::coordinator::run_suite;
use pisa_nmc::testkit::bench::bench_scale;
use pisa_nmc::workloads::{registry, scaled_n};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    println!("== profiler throughput (scale {scale}) ==\n");

    // end-to-end suite: chunked pipeline, all analyzers + sims
    let t0 = Instant::now();
    let apps = run_suite(scale, 42, 8)?;
    let suite_s = t0.elapsed().as_secs_f64();
    let total_events: u64 = apps.iter().map(|a| a.metrics.exec.events()).sum();

    println!("{:<14} {:>14} {:>10} {:>14}", "app", "events", "wall", "events/s");
    for a in &apps {
        println!(
            "{:<14} {:>14} {:>9.3}s {:>13.2}M",
            a.name,
            a.metrics.exec.events(),
            a.metrics.exec.wall_s,
            a.events_per_sec() / 1e6,
        );
    }
    println!(
        "\nsuite: {total_events} events in {suite_s:.3}s wall ({:.2}M events/s end-to-end; worker threads overlap)\n",
        total_events as f64 / suite_s / 1e6,
    );

    // chunked vs per-event dispatch, single-threaded, analyzers only —
    // isolates the event-delivery cost the refactor removed
    println!("{:<14} {:>12} {:>12} {:>8}", "app", "per-event", "chunked", "speedup");
    let (mut tot_ref, mut tot_chunk) = (0.0f64, 0.0f64);
    for k in registry() {
        let n = scaled_n(k.as_ref(), scale);
        let prog = k.build(n, 42);
        let t = Instant::now();
        let r = profile_per_event(&prog)?;
        let ref_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let c = profile(&prog)?;
        let chunk_s = t.elapsed().as_secs_f64();
        assert_eq!(r.exec.dyn_instrs, c.exec.dyn_instrs);
        tot_ref += ref_s;
        tot_chunk += chunk_s;
        println!(
            "{:<14} {:>11.3}s {:>11.3}s {:>7.2}x",
            k.info().name,
            ref_s,
            chunk_s,
            ref_s / chunk_s
        );
    }
    println!(
        "\ntotal: per-event {tot_ref:.3}s, chunked {tot_chunk:.3}s → {:.2}x",
        tot_ref / tot_chunk
    );
    Ok(())
}
