//! Suite-level profiler throughput: the wall-clock number the chunked +
//! off-thread event pipeline is accountable to. Runs the suite at the
//! default bench scale (override with `PISA_BENCH_SCALE`) in all three
//! [`PipelineMode`]s — inline, offload (one analysis thread), sharded
//! (family-sharded analyzer worker pool) — reports total trace events per
//! second of end-to-end suite time, then runs every kernel through all
//! four delivery paths (per-event reference, inline chunked, offloaded,
//! sharded) for the per-app dispatch/overlap comparison.
//!
//! A further inline arm runs with the `traffic` family disabled, so the
//! memory-traffic subsystem's events/s overhead (budget: ≤ 25% vs the
//! default all-families stack) is measured on every run. A scheduler arm
//! re-runs the inline suite with `--jobs auto` (concurrent per-app jobs
//! under the shared worker budget) against the `--jobs 1` baseline.
//!
//! With `--bench-json` the suite numbers land in `BENCH_pipeline.json` at
//! the repo root, so successive PRs have a perf trajectory to diff
//! against — the CI `bench` job uploads that file as a workflow artifact
//! and renders its suite table into the job summary.
//!
//! ```bash
//! cargo bench --bench throughput                     # scale 0.25
//! PISA_BENCH_SCALE=1.0 cargo bench --bench throughput
//! cargo bench --bench throughput -- --bench-json     # + BENCH_pipeline.json
//! ```

use std::time::Instant;

use pisa_nmc::analysis::{profile, profile_per_event, profile_source_opts, Metric, MetricSet};
use pisa_nmc::coordinator::{AppResult, Jobs, ProfileRequest, RunCtx};
use pisa_nmc::interp::{Machine, PipelineMode, Workers};
use pisa_nmc::testkit::bench::bench_scale;
use pisa_nmc::trace::{TraceLanes, TraceMeta, TraceReader, TraceWriter};
use pisa_nmc::traffic::{MrcMode, TrafficOpts};
use pisa_nmc::util::Json;
use pisa_nmc::workloads::{registry, scaled_n};

/// One end-to-end suite run; returns per-app results and events/s of wall.
fn suite_arm(
    scale: f64,
    metrics: MetricSet,
    mode: PipelineMode,
    jobs: Jobs,
) -> anyhow::Result<(Vec<AppResult>, f64)> {
    let t0 = Instant::now();
    let apps = ProfileRequest::suite(scale, 42)
        .metrics(metrics)
        .mode(mode)
        .jobs(jobs)
        .run_apps(&RunCtx::new())?;
    let suite_s = t0.elapsed().as_secs_f64();
    let total_events: u64 = apps.iter().map(|a| a.metrics.exec.events()).sum();
    Ok((apps, total_events as f64 / suite_s))
}

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let emit_json = std::env::args().any(|a| a == "--bench-json");
    println!("== profiler throughput (scale {scale}) ==\n");

    // end-to-end suite in every delivery mode: all analyzers + sims
    let sharded_mode = PipelineMode::Sharded { workers: Workers::Auto };
    let one = Jobs::Fixed(1);
    let (inline_apps, inline_eps) = suite_arm(scale, MetricSet::all(), PipelineMode::Inline, one)?;
    let (offload_apps, offload_eps) =
        suite_arm(scale, MetricSet::all(), PipelineMode::Offload, one)?;
    let (sharded_apps, sharded_eps) = suite_arm(scale, MetricSet::all(), sharded_mode, one)?;
    // the traffic-subsystem overhead arm: same inline suite minus the
    // traffic family (its budget: ≤ 25% events/s overhead vs this arm)
    let (_, no_traffic_eps) =
        suite_arm(scale, MetricSet::all().without(Metric::Traffic), PipelineMode::Inline, one)?;
    // suite scheduler arm (ISSUE 9): the same inline all-families suite
    // through the concurrent scheduler — `--jobs auto` vs the `--jobs 1`
    // baseline (inline_eps above). App-level parallelism, bit-identical
    // results (prop_sched.rs), so the only question is wall-clock.
    let (_, jobs_auto_eps) = suite_arm(scale, MetricSet::all(), PipelineMode::Inline, Jobs::Auto)?;

    println!(
        "{:<14} {:>14} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "app", "events", "inline", "offload", "sharded", "ovlp", "shard"
    );
    for ((a, o), sh) in inline_apps.iter().zip(&offload_apps).zip(&sharded_apps) {
        println!(
            "{:<14} {:>14} {:>10.2}M/s {:>10.2}M/s {:>10.2}M/s {:>7.2}x {:>7.2}x",
            a.name,
            a.metrics.exec.events(),
            a.events_per_sec() / 1e6,
            o.events_per_sec() / 1e6,
            sh.events_per_sec() / 1e6,
            o.events_per_sec() / a.events_per_sec().max(1e-9),
            sh.events_per_sec() / a.events_per_sec().max(1e-9),
        );
    }
    println!(
        "\nsuite end-to-end: inline {:.2}M events/s, offload {:.2}M events/s ({:.2}x), \
         sharded {:.2}M events/s ({:.2}x)",
        inline_eps / 1e6,
        offload_eps / 1e6,
        offload_eps / inline_eps.max(1e-9),
        sharded_eps / 1e6,
        sharded_eps / inline_eps.max(1e-9),
    );
    let traffic_overhead_pct = (no_traffic_eps / inline_eps.max(1e-9) - 1.0) * 100.0;
    println!(
        "traffic overhead: enabled {:.2}M events/s vs disabled {:.2}M events/s → {:.1}% \
         (budget ≤ 25%)",
        inline_eps / 1e6,
        no_traffic_eps / 1e6,
        traffic_overhead_pct,
    );
    println!(
        "suite scheduler: --jobs 1 {:.2}M events/s vs --jobs auto {:.2}M events/s ({:.2}x)\n",
        inline_eps / 1e6,
        jobs_auto_eps / 1e6,
        jobs_auto_eps / inline_eps.max(1e-9),
    );

    // four-way dispatch comparison, single app at a time, analyzers only —
    // isolates the event-delivery cost (per-event virtual calls vs chunked
    // lane sweeps vs one-thread overlap vs the family-sharded worker pool)
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "app", "per-event", "inline", "offload", "sharded", "chunk x", "shard x"
    );
    let (mut tot_ref, mut tot_inline, mut tot_offload, mut tot_sharded) =
        (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let ctx = RunCtx::new();
    for k in registry() {
        let n = scaled_n(k.as_ref(), scale);
        let prog = k.build(n, 42);
        let t = Instant::now();
        let r = profile_per_event(&prog)?;
        let ref_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let c = profile(&prog)?;
        let inline_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let o = ProfileRequest::program(&prog).mode(PipelineMode::Offload).run_metrics(&ctx)?;
        let offload_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let sh = ProfileRequest::program(&prog).mode(sharded_mode).run_metrics(&ctx)?;
        let sharded_s = t.elapsed().as_secs_f64();
        assert_eq!(r.exec.dyn_instrs, c.exec.dyn_instrs);
        assert_eq!(c.exec.dyn_instrs, o.exec.dyn_instrs);
        assert_eq!(c.exec.dyn_instrs, sh.exec.dyn_instrs);
        tot_ref += ref_s;
        tot_inline += inline_s;
        tot_offload += offload_s;
        tot_sharded += sharded_s;
        println!(
            "{:<14} {:>11.3}s {:>11.3}s {:>11.3}s {:>11.3}s {:>7.2}x {:>7.2}x",
            k.info().name,
            ref_s,
            inline_s,
            offload_s,
            sharded_s,
            ref_s / inline_s,
            inline_s / sharded_s,
        );
    }
    println!(
        "\ntotal: per-event {tot_ref:.3}s, inline {tot_inline:.3}s, offload {tot_offload:.3}s, \
         sharded {tot_sharded:.3}s"
    );
    println!(
        "       chunked dispatch {:.2}x, offload overlap {:.2}x, sharded pool {:.2}x (vs inline)",
        tot_ref / tot_inline,
        tot_inline / tot_offload,
        tot_inline / tot_sharded
    );

    // SHARDS sampling arms (ISSUE 6): traffic family alone, exact vs
    // sampled:0.01 — first across the whole suite, then on the single
    // largest-footprint kernel (where the exact Olken/Fenwick kernel's
    // O(log footprint) per access bites hardest; acceptance: ≥ 2×)
    let traffic_only = MetricSet::from_names("traffic")?;
    let sampled_opts = TrafficOpts::default().with_mrc(MrcMode::Sampled { rate: 0.01 });
    let t = Instant::now();
    let exact_apps =
        ProfileRequest::suite(scale, 42).metrics(traffic_only).jobs(one).run_apps(&ctx)?;
    let mrc_exact_s = t.elapsed().as_secs_f64();
    let suite_events: u64 = exact_apps.iter().map(|a| a.metrics.exec.events()).sum();
    let t = Instant::now();
    ProfileRequest::suite(scale, 42)
        .metrics(traffic_only)
        .traffic(sampled_opts)
        .jobs(one)
        .run_apps(&ctx)?;
    let mrc_sampled_s = t.elapsed().as_secs_f64();
    let mrc_exact_eps = suite_events as f64 / mrc_exact_s.max(1e-9);
    let mrc_sampled_eps = suite_events as f64 / mrc_sampled_s.max(1e-9);
    println!(
        "\ntraffic-only suite: exact {:.2}M events/s vs sampled:0.01 {:.2}M events/s ({:.2}x)",
        mrc_exact_eps / 1e6,
        mrc_sampled_eps / 1e6,
        mrc_sampled_eps / mrc_exact_eps.max(1e-9),
    );
    let biggest = exact_apps
        .iter()
        .max_by_key(|a| a.metrics.traffic.footprint_lines)
        .expect("suite is non-empty");
    let kernel_name = biggest.name.clone();
    let kernel_lines = biggest.metrics.traffic.footprint_lines;
    let kprog = {
        let k = registry().into_iter().find(|k| k.info().name == kernel_name).unwrap();
        k.build(biggest.n, 42)
    };
    let t = Instant::now();
    let ke = ProfileRequest::program(&kprog).metrics(traffic_only).run_metrics(&ctx)?;
    let kernel_exact_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    ProfileRequest::program(&kprog)
        .metrics(traffic_only)
        .traffic(sampled_opts)
        .run_metrics(&ctx)?;
    let kernel_sampled_s = t.elapsed().as_secs_f64();
    let kernel_events = ke.exec.events() as f64;
    let kernel_exact_eps = kernel_events / kernel_exact_s.max(1e-9);
    let kernel_sampled_eps = kernel_events / kernel_sampled_s.max(1e-9);
    println!(
        "largest footprint ({kernel_name}, {kernel_lines} lines): exact {:.2}M events/s vs \
         sampled:0.01 {:.2}M events/s ({:.2}x)",
        kernel_exact_eps / 1e6,
        kernel_sampled_eps / 1e6,
        kernel_sampled_eps / kernel_exact_eps.max(1e-9),
    );

    // trace record/replay arm (ISSUE 8): interpret-and-analyze vs
    // decode-and-analyze the same events from a .pallas-trace recording —
    // the replay path skips execution (register file, memory image,
    // control flow) and pays decode instead, so its events/s is the
    // subsystem's headline number. Same kernel as the MRC arm above.
    let all_metrics = MetricSet::all();
    let dflt = TrafficOpts::default();
    let t = Instant::now();
    let live = ProfileRequest::program(&kprog).metrics(all_metrics).run_metrics(&ctx)?;
    let interp_s = t.elapsed().as_secs_f64();
    let trace_path = std::env::temp_dir().join("pisa-bench-trace.pallas-trace");
    {
        let mut machine = Machine::new(&kprog)?;
        let meta = TraceMeta { app: kernel_name.clone(), n: biggest.n as u64, seed: 42 };
        let mut w =
            TraceWriter::create(&trace_path, meta, machine.chunk_capacity(), TraceLanes::ALL)?;
        machine.run(&mut w)?;
        w.finish()?;
    }
    let t = Instant::now();
    let mut reader = TraceReader::open(&trace_path)?;
    let replayed =
        profile_source_opts(&kprog, &mut reader, all_metrics, PipelineMode::Inline, dflt)?;
    let replay_s = t.elapsed().as_secs_f64();
    std::fs::remove_file(&trace_path).ok();
    assert_eq!(live.exec.dyn_instrs, replayed.exec.dyn_instrs);
    let trace_events = live.exec.events() as f64;
    let interp_eps = trace_events / interp_s.max(1e-9);
    let replay_eps = trace_events / replay_s.max(1e-9);
    println!(
        "\ntrace replay ({kernel_name}): interpret+analyze {:.2}M events/s vs decode+analyze \
         {:.2}M events/s ({:.2}x)",
        interp_eps / 1e6,
        replay_eps / 1e6,
        replay_eps / interp_eps.max(1e-9),
    );

    if emit_json {
        let mut j = Json::obj();
        j.set("scale", scale);
        let mut suite = Json::obj();
        suite.set("inline_events_per_sec", inline_eps);
        suite.set("offload_events_per_sec", offload_eps);
        suite.set("offload_speedup", offload_eps / inline_eps.max(1e-9));
        suite.set("sharded_events_per_sec", sharded_eps);
        suite.set("sharded_speedup", sharded_eps / inline_eps.max(1e-9));
        j.set("suite", suite);
        // suite scheduler wall-clock: `--jobs auto` vs the `--jobs 1`
        // inline baseline — app-level parallelism under the shared
        // worker budget, bit-identical results
        let mut sched = Json::obj();
        sched.set("jobs1_events_per_sec", inline_eps);
        sched.set("jobs_auto_events_per_sec", jobs_auto_eps);
        sched.set("jobs_auto_speedup", jobs_auto_eps / inline_eps.max(1e-9));
        j.set("sched", sched);
        // traffic-subsystem overhead trend: events/s with the traffic
        // family enabled (the default stack) vs disabled, same inline
        // delivery — budget ≤ 25%
        let mut traffic = Json::obj();
        traffic.set("enabled_events_per_sec", inline_eps);
        traffic.set("disabled_events_per_sec", no_traffic_eps);
        traffic.set("overhead_pct", traffic_overhead_pct);
        j.set("traffic", traffic);
        // exact vs SHARDS-sampled MRC (traffic family alone, inline):
        // the perf claim `--mrc sampled:0.01` is accountable to (≥ 2× on
        // the largest-footprint kernel)
        let mut mrc = Json::obj();
        mrc.set("rate", 0.01);
        mrc.set("suite_exact_events_per_sec", mrc_exact_eps);
        mrc.set("suite_sampled_events_per_sec", mrc_sampled_eps);
        mrc.set("suite_speedup", mrc_sampled_eps / mrc_exact_eps.max(1e-9));
        mrc.set("kernel", kernel_name.as_str());
        mrc.set("kernel_footprint_lines", kernel_lines);
        mrc.set("kernel_exact_events_per_sec", kernel_exact_eps);
        mrc.set("kernel_sampled_events_per_sec", kernel_sampled_eps);
        mrc.set("kernel_speedup", kernel_sampled_eps / kernel_exact_eps.max(1e-9));
        j.set("mrc_sampled", mrc);
        // trace-replay throughput: decoding a .pallas-trace recording
        // into the full analyzer stack vs interpreting the kernel live
        let mut trace = Json::obj();
        trace.set("kernel", kernel_name.as_str());
        trace.set("interp_events_per_sec", interp_eps);
        trace.set("replay_events_per_sec", replay_eps);
        trace.set("replay_speedup", replay_eps / interp_eps.max(1e-9));
        j.set("trace", trace);
        let mut apps = Json::obj();
        for ((a, o), sh) in inline_apps.iter().zip(&offload_apps).zip(&sharded_apps) {
            let mut app = Json::obj();
            app.set("events", a.metrics.exec.events());
            app.set("inline_events_per_sec", a.events_per_sec());
            app.set("offload_events_per_sec", o.events_per_sec());
            app.set("sharded_events_per_sec", sh.events_per_sec());
            apps.set(&a.name, app);
        }
        j.set("apps", apps);
        let path = std::path::Path::new("BENCH_pipeline.json");
        pisa_nmc::report::save_json(path, &j)?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
