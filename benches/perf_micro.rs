//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): interpreter dispatch,
//! each streaming analyzer, the machine simulators and the PJRT artifact
//! call. These are the numbers the optimization pass tracks.

use pisa_nmc::analysis::{
    AnalyzerStack, BblpAnalyzer, DlpAnalyzer, IlpAnalyzer, MemEntropyAnalyzer, MetricSet,
    MixAnalyzer, PbblpAnalyzer, ReuseAnalyzer, ShardPlan,
};
use pisa_nmc::interp::{
    run_program, run_sharded, Fanout, Instrument, Machine, NullInstrument, Workers,
};
use pisa_nmc::ir::ProgramBuilder;
use pisa_nmc::runtime::Runtime;
use pisa_nmc::sim::{collect, simulate_host, simulate_nmc};
use pisa_nmc::testkit::bench::bench;
use pisa_nmc::util::Rng;
use pisa_nmc::workloads::by_name;

/// Medium workload used across micro benches (~1.4M dynamic instrs).
fn workload() -> pisa_nmc::ir::Program {
    by_name("gesummv").unwrap().build(128, 42)
}

fn dyn_instrs(p: &pisa_nmc::ir::Program) -> u64 {
    let (out, _) = run_program(p, &mut NullInstrument).unwrap();
    out.stats.dyn_instrs
}

fn run_with(p: &pisa_nmc::ir::Program, sink: &mut dyn Instrument) {
    run_program(p, sink).unwrap();
}

fn main() -> anyhow::Result<()> {
    println!("== hot-path microbenchmarks ==\n");
    let prog = workload();
    let n = dyn_instrs(&prog);
    println!("workload: gesummv n=128, {n} dynamic instructions\n");

    bench("interp_dispatch (NullInstrument)", 1, 8, Some((n, "instr")), || {
        run_with(&prog, &mut NullInstrument)
    });

    // The headline comparison for the chunked-pipeline refactor: the same
    // full analyzer set driven per-event through the legacy Fanout (one
    // virtual call per analyzer per dynamic event) vs chunked through the
    // AnalyzerStack (one virtual call per ~4K-event chunk, static dispatch
    // inside). gesummv is memory-heavy, so every analyzer is on its slow
    // path.
    bench("dispatch_per_event (Fanout, 8 analyzers)", 1, 3, Some((n, "instr")), || {
        let mut mix = MixAnalyzer::new();
        let mut branch = pisa_nmc::analysis::BranchAnalyzer::new();
        let mut ment = MemEntropyAnalyzer::new();
        let mut reuse = ReuseAnalyzer::new();
        let mut ilp = IlpAnalyzer::new(prog.func.n_regs);
        let mut dlp = DlpAnalyzer::for_program(&prog);
        let mut bblp = BblpAnalyzer::new(prog.func.n_regs);
        let mut pbblp = PbblpAnalyzer::new(&prog);
        let mut fan = Fanout::new(vec![
            &mut mix, &mut branch, &mut ment, &mut reuse, &mut ilp, &mut dlp, &mut bblp,
            &mut pbblp,
        ]);
        let mut m = Machine::new(&prog).unwrap();
        std::hint::black_box(m.run_per_event(&mut fan).unwrap());
    });
    bench("dispatch_chunked (AnalyzerStack)", 1, 3, Some((n, "instr")), || {
        // same analyzer set, same un-finalized endpoint as the arm above
        let mut stack = AnalyzerStack::full(&prog);
        let mut m = Machine::new(&prog).unwrap();
        std::hint::black_box(m.run(&mut stack).unwrap());
    });
    bench("dispatch_offload (AnalyzerStack, analysis thread)", 1, 3, Some((n, "instr")), || {
        // same stack, folding on a dedicated thread overlapped with the
        // interpreter (chunks cross the bounded offload channel)
        let mut stack = AnalyzerStack::full(&prog);
        let mut m = Machine::new(&prog).unwrap();
        std::hint::black_box(pisa_nmc::interp::run_offload(&mut m, &mut stack).unwrap());
    });
    bench("dispatch_sharded (family-sharded worker pool, auto)", 1, 3, Some((n, "instr")), || {
        // same analyzer set, sharded by family across the auto-sized
        // worker pool, each chunk broadcast to all of them — same
        // un-finalized endpoint as the arms above
        let plan = ShardPlan::new(MetricSet::all(), Workers::Auto);
        let mut stacks: Vec<AnalyzerStack> =
            plan.shards().iter().map(|&s| AnalyzerStack::new(&prog, s)).collect();
        let mut refs: Vec<&mut (dyn Instrument + Send)> =
            stacks.iter_mut().map(|s| s as &mut (dyn Instrument + Send)).collect();
        let mut m = Machine::new(&prog).unwrap();
        std::hint::black_box(run_sharded(&mut m, &mut refs).unwrap());
    });
    bench("analyzer_mix", 1, 5, Some((n, "instr")), || {
        let mut a = MixAnalyzer::new();
        run_with(&prog, &mut a);
    });
    bench("analyzer_mem_entropy", 1, 5, Some((n, "instr")), || {
        let mut a = MemEntropyAnalyzer::new();
        run_with(&prog, &mut a);
        std::hint::black_box(a.finalize(4096));
    });
    bench("analyzer_reuse (8 line sizes, exact)", 1, 3, Some((n, "instr")), || {
        let mut a = ReuseAnalyzer::new();
        run_with(&prog, &mut a);
        std::hint::black_box(a.finalize());
    });
    bench("traffic_sweep (MRC + 3-level hierarchy + bytes)", 1, 3, Some((n, "instr")), || {
        // the traffic subsystem alone, sweeping the addr/size/store lanes:
        // one Olken stack at 64B lines + the L1→L2→LLC replay + byte tallies
        let mut a = pisa_nmc::traffic::TrafficAnalyzer::new();
        run_with(&prog, &mut a);
        std::hint::black_box(a.finalize(n));
    });
    bench("traffic_sweep (exclusive hierarchy)", 1, 3, Some((n, "instr")), || {
        // the exclusive policy moves lines between levels on every lower
        // hit — measure its cost next to the inclusive arm above
        let mut a = pisa_nmc::traffic::TrafficAnalyzer::with_policy(
            pisa_nmc::traffic::HierarchyPolicy::Exclusive,
        );
        run_with(&prog, &mut a);
        std::hint::black_box(a.finalize(n));
    });
    // The SHARDS comparison (ISSUE 6): the exact Olken/Fenwick MRC kernel
    // vs fixed-rate sampling vs the fixed-size adaptive variant, on the
    // captured address stream of the largest-footprint workload we bench
    // (gesummv n=256: ~1M doubles → ~16k distinct 64B lines). Stream
    // capture is outside the timed region so the arms measure only the
    // stack-distance kernels.
    struct AddrCapture(Vec<u64>);
    impl Instrument for AddrCapture {
        fn on_event(&mut self, ev: &pisa_nmc::interp::TraceEvent) {
            if let pisa_nmc::interp::TraceEvent::Instr(e) = ev {
                if let Some(m) = e.mem {
                    self.0.push(m.addr);
                }
            }
        }
    }
    let big = by_name("gesummv").unwrap().build(256, 42);
    let mut cap = AddrCapture(Vec::new());
    run_program(&big, &mut cap).unwrap();
    let mrc_addrs = cap.0;
    let na = mrc_addrs.len() as u64;
    println!("\nmrc kernel arms: gesummv n=256, {na} memory accesses");
    bench("mrc_exact (Olken/Fenwick)", 1, 5, Some((na, "access")), || {
        let mut b = pisa_nmc::traffic::MrcBuilder::new();
        for &a in &mrc_addrs {
            b.access(a);
        }
        std::hint::black_box(b.miss_counts());
    });
    bench("mrc_sampled (SHARDS, rate 0.01)", 1, 5, Some((na, "access")), || {
        let mut s = pisa_nmc::traffic::SampledMrc::new(0.01);
        for &a in &mrc_addrs {
            s.access(a);
        }
        std::hint::black_box(s.miss_ratios());
    });
    bench("mrc_sampled_fixed (S_max 8192, rate-adaptive)", 1, 5, Some((na, "access")), || {
        let mut s =
            pisa_nmc::traffic::SampledMrc::fixed_size(pisa_nmc::traffic::DEFAULT_SAMPLE_S_MAX);
        for &a in &mrc_addrs {
            s.access(a);
        }
        std::hint::black_box(s.miss_ratios());
    });

    bench("analyzer_ilp (4 windows + inf)", 1, 3, Some((n, "instr")), || {
        let mut a = IlpAnalyzer::new(prog.func.n_regs);
        run_with(&prog, &mut a);
    });
    bench("analyzer_dlp", 1, 5, Some((n, "instr")), || {
        let mut a = DlpAnalyzer::for_program(&prog);
        run_with(&prog, &mut a);
    });
    bench("analyzer_bblp (4 windows)", 1, 3, Some((n, "instr")), || {
        let mut a = BblpAnalyzer::new(prog.func.n_regs);
        run_with(&prog, &mut a);
        std::hint::black_box(a.finalize());
    });
    bench("analyzer_pbblp", 1, 5, Some((n, "instr")), || {
        let mut a = PbblpAnalyzer::new(&prog);
        run_with(&prog, &mut a);
        std::hint::black_box(a.finalize());
    });

    // standalone structure benches
    let mut rng = Rng::new(7);
    let addrs: Vec<u64> = (0..200_000).map(|_| 0x1_0000 + rng.below(1 << 16) * 8).collect();
    bench("reuse_fenwick_200k_random", 1, 5, Some((200_000, "access")), || {
        let mut a = ReuseAnalyzer::new();
        for &ad in &addrs {
            a.record(ad);
        }
        std::hint::black_box(a.finalize());
    });

    let regions = collect(&prog)?;
    bench("sim_host", 1, 5, Some((n, "instr")), || {
        std::hint::black_box(simulate_host(&regions, 2.5))
    });
    bench("sim_nmc (32 PEs, 32 vaults)", 1, 5, Some((n, "instr")), || {
        std::hint::black_box(simulate_nmc(&regions))
    });

    // DRAM timing model alone
    bench("dram_model_1M_requests", 1, 3, Some((1_000_000, "req")), || {
        let mut d = pisa_nmc::sim::dram::Dram::new(pisa_nmc::sim::DramConfig::hmc_vault());
        let mut now = 0u64;
        let mut rng = Rng::new(1);
        for _ in 0..1_000_000 {
            let s = d.request(rng.below(1 << 24) * 64, now);
            now = s.done;
        }
        std::hint::black_box(d.row_hit_rate())
    });

    if let Ok(rt) = Runtime::load_default() {
        let g = rt.manifest().shape("G")?;
        let b = rt.manifest().shape("B")?;
        let counts = vec![1.0f32; g * b];
        let weights = vec![1.0f32; g * b];
        bench("pjrt_entropy_execute (16x4096)", 2, 20, None, || {
            std::hint::black_box(rt.execute("entropy", &[&counts, &weights]).unwrap())
        });
        let x = vec![0.5f32; rt.manifest().shape("N")? * 4];
        let mask = vec![1.0f32; rt.manifest().shape("N")?];
        bench("pjrt_pca4_execute", 2, 20, None, || {
            std::hint::black_box(rt.execute("pca4", &[&x, &mask]).unwrap())
        });
    } else {
        println!("(pjrt benches skipped: run `make artifacts`)");
    }

    // end-to-end single app
    let k = by_name("mvt").unwrap();
    bench("profile_app_end_to_end (mvt n=96)", 1, 3, None, || {
        std::hint::black_box(pisa_nmc::coordinator::profile_app(k.as_ref(), 96, 1).unwrap())
    });
    Ok(())
}
