//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A. dependency-aware vs dependency-blind NMC task scheduling — the
//!      paper's Ramulator trace replay is dependency-blind; our region
//!      extraction is dataflow-faithful. cholesky is where they diverge.
//!   B. count-of-counts exact entropy vs plain fixed-bucket histograms —
//!      why the entropy artifact ships the (count, multiplicity) ABI.
//!   C. vault interleave granularity sweep — locality/parallelism tradeoff.
//!   D. NMC PE L1 size sweep — Table 1's 2-line cache vs roomier PEs.

use pisa_nmc::analysis::MemEntropyAnalyzer;
use pisa_nmc::sim::{collect, EnergyConfig, NmcConfig, NmcSystem, Region, Task};
use pisa_nmc::testkit::bench::bench_scale;
use pisa_nmc::util::stats::shannon_entropy_counts;
use pisa_nmc::util::Rng;
use pisa_nmc::workloads::{by_name, scaled_n};

/// Dependency-blind transform: split every serial region into 32
/// equal-ish pseudo-tasks (what a pure trace-slicing replayer would do).
fn blind(regions: &[Region]) -> Vec<Region> {
    regions
        .iter()
        .map(|r| match r {
            Region::Parallel(ts) => Region::Parallel(ts.clone()),
            Region::Serial(t) => {
                if t.accesses.len() < 64 {
                    return Region::Serial(t.clone());
                }
                let chunks = 32usize;
                let per = t.accesses.len().div_ceil(chunks);
                let tasks: Vec<Task> = t
                    .accesses
                    .chunks(per)
                    .map(|acc| Task {
                        simple_ops: t.simple_ops / chunks as u64,
                        heavy_ops: t.heavy_ops / chunks as u64,
                        accesses: acc.to_vec(),
                    })
                    .collect();
                Region::Parallel(tasks)
            }
        })
        .collect()
}

fn nmc_with(cfg: NmcConfig, regions: &[Region]) -> pisa_nmc::sim::NmcResult {
    NmcSystem::new(cfg, EnergyConfig::default()).run(regions)
}

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    println!("== ablation A: dependency-aware vs dependency-blind scheduling ==");
    println!("(the paper's replay methodology is blind; cholesky is the divergence)\n");
    println!(
        "{:<12} {:>14} {:>14} {:>10}",
        "app", "aware t (ms)", "blind t (ms)", "blind/aware"
    );
    for name in ["cholesky", "gramschmidt", "atax", "bfs"] {
        let k = by_name(name)?;
        let prog = k.build(scaled_n(k.as_ref(), scale), 42);
        let regions = collect(&prog)?;
        let aware = nmc_with(NmcConfig::default(), &regions);
        let blind_r = nmc_with(NmcConfig::default(), &blind(&regions));
        println!(
            "{:<12} {:>14.3} {:>14.3} {:>10.2}",
            name,
            aware.time_s * 1e3,
            blind_r.time_s * 1e3,
            blind_r.time_s / aware.time_s
        );
    }

    println!("\n== ablation B: exact count-of-counts entropy vs fixed-bucket histogram ==\n");
    let mut rng = Rng::new(11);
    let mut an = MemEntropyAnalyzer::new();
    // zipf-ish address stream: hot set + long tail
    for _ in 0..400_000u64 {
        let addr = if rng.below(2) == 0 {
            rng.below(256) * 8
        } else {
            rng.below(1 << 20) * 8
        };
        an.record(0x1_0000 + addr);
    }
    let exact = an.finalize(4096);
    // plain-histogram approximation: hash addresses into 4096 buckets
    let mut buckets = vec![0u64; 4096];
    let mut rng = Rng::new(11);
    for _ in 0..400_000u64 {
        let addr = if rng.below(2) == 0 {
            rng.below(256) * 8
        } else {
            rng.below(1 << 20) * 8
        };
        let a = 0x1_0000 + addr;
        buckets[(a.wrapping_mul(0x9E3779B97F4A7C15) >> 52) as usize] += 1;
    }
    let approx = shannon_entropy_counts(buckets.iter().copied());
    println!(
        "exact byte-granularity entropy : {:.4} bits (count-of-counts ABI)",
        exact.entropies[0]
    );
    println!("4096-bucket hashed histogram   : {approx:.4} bits");
    println!(
        "approximation error            : {:.2} bits — why the artifact ships (count, multiplicity) pairs\n",
        (exact.entropies[0] - approx).abs()
    );

    println!("== ablation C: vault interleave granularity (gramschmidt) ==\n");
    let k = by_name("gramschmidt")?;
    let prog = k.build(scaled_n(k.as_ref(), scale), 42);
    let regions = collect(&prog)?;
    println!("{:>10} {:>12} {:>12} {:>12}", "granule", "t (ms)", "remote frac", "EDP (J*s)");
    for granule in [256u64, 1024, 2048, 8192, 65536] {
        let cfg = NmcConfig { vault_block_bytes: granule, ..NmcConfig::default() };
        let r = nmc_with(cfg, &regions);
        println!(
            "{:>10} {:>12.3} {:>12.2} {:>12.3e}",
            granule,
            r.time_s * 1e3,
            r.remote_lines as f64 / r.dram_lines.max(1) as f64,
            r.edp()
        );
    }

    println!("\n== ablation D: NMC PE L1 size (Table 1 says 2 lines) ==\n");
    println!("{:>10} {:>12} {:>14}", "L1 lines", "t (ms)", "DRAM lines");
    for lines in [2usize, 8, 64, 512] {
        let cfg = NmcConfig { l1_lines: lines, ..NmcConfig::default() };
        let r = nmc_with(cfg, &regions);
        println!("{:>10} {:>12.3} {:>14}", lines, r.time_s * 1e3, r.dram_lines);
    }
    Ok(())
}
