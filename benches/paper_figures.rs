//! Regenerates EVERY table and figure of the paper's evaluation and times
//! each stage (the `cargo bench` target for the reproduction itself).
//!
//! Set `PISA_BENCH_SCALE=1.0` to regenerate the EXPERIMENTS.md numbers
//! exactly (≈1–2 min); the default 0.25 keeps the shape at reduced size.

use pisa_nmc::analysis::MetricSet;
use pisa_nmc::coordinator::{analyze_suite, figures, run_suite};
use pisa_nmc::runtime::Runtime;
use pisa_nmc::testkit::bench::{bench, bench_scale};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    println!("== paper figure/table regeneration (scale {scale}) ==\n");

    // Tables are config renders — timed for completeness, printed once.
    bench("table1_render", 2, 20, None, figures::table1);
    bench("table2_render", 2, 20, None, || figures::table2(scale));
    println!("\n{}", figures::table1());
    println!("{}", figures::table2(scale));

    // The profiling pass dominates; do it once and time it.
    let mut apps = Vec::new();
    bench("suite_profile_and_simulate", 0, 1, None, || {
        apps = run_suite(scale, 42, 8).expect("suite");
    });

    let rt = Runtime::load_default().ok();
    println!(
        "analytics engine: {}",
        if rt.is_some() { "pjrt" } else { "native (run `make artifacts`)" }
    );
    let mut analytics = None;
    bench("suite_analytics (entropy+spatial+pca)", 0, 3, None, || {
        analytics = Some(analyze_suite(&apps, rt.as_ref()).expect("analytics"));
    });
    let analytics = analytics.unwrap();

    let all = MetricSet::all();
    let figs: Vec<(&str, String)> = vec![
        ("fig3a", figures::fig3a(&apps, &analytics, all).0),
        ("fig3b", figures::fig3b(&apps, &analytics, all).0),
        ("fig3c", figures::fig3c(&apps, all).0),
        ("fig4", figures::fig4(&apps).0),
        ("fig5", figures::fig5(&apps, &analytics, all).0),
        ("fig6", figures::fig6(&apps, &analytics, all).0),
        ("fig_mrc", figures::fig_mrc(&apps, all).0),
    ];
    for (name, text) in &figs {
        bench(&format!("{name}_render"), 1, 10, None, || match *name {
            "fig3a" => figures::fig3a(&apps, &analytics, all).0.len(),
            _ => text.len(),
        });
    }
    println!();
    for (_, text) in figs {
        println!("{text}");
    }
    Ok(())
}
